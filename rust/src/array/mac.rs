//! MAC semantics shared by both flavors: 16-row groups, per-group ADC
//! saturation, and the reference (pure-integer) implementations that the
//! analog simulations and the AOT Pallas kernel are all tested against.
//!
//! Saturation semantics (§III.2, §IV.3):
//! - SiTe CiM I digitizes the two RBL counts *separately* with two 3-bit
//!   ADCs (+ extra SA): O = min(a, 8) − min(b, 8).
//! - SiTe CiM II subtracts *first* (comparator + analog subtractor) and
//!   digitizes the magnitude with one ADC: O = sign(a−b)·min(|a−b|, 8).
//! Both approximate outputs beyond 8 as 8; they differ when a and b are
//! simultaneously large (e.g. a=10, b=9 → CiM I: 0, CiM II: +1).
//!
//! # Region-scoped kernels
//!
//! The engine packs several weight shards into one physical array, each
//! on a 16-row-aligned [`Rect`]. The paper's array-level win is that a
//! dot product only cycles the rows/columns it actually occupies, so the
//! region kernels ([`dot_region_cim1`], [`dot_region_cim2`],
//! [`dot_region_exact`]) compute exactly what the full-array batch MAC
//! would produce for inputs that are zero outside the region, restricted
//! to the region's column span — at a cost proportional to the occupied
//! window, not the whole array. Semantics are *defined* by that
//! equivalence: `dot_region_*(rect, x) == dot_batch(pad(x))[cols of
//! rect]` bit-for-bit (zero inputs are electrically inert, so the
//! skipped rows/cycles contribute exactly nothing; for CiM II the
//! full-array stride grouping is preserved — only the per-cycle popcount
//! is restricted to the region's word span).

use super::encoding::Trit;
use super::storage::{pack_inputs16, pack_inputs_words, TernaryStorage};

/// Rows asserted per MAC cycle (N_A in the paper).
pub const GROUP_ROWS: usize = 16;
/// ADC saturation code.
pub const SAT: u32 = 8;

/// A row/col sub-rectangle of one physical array — where a placed shard
/// lives and what the region-scoped MAC kernels cycle. `row0` and `rows`
/// are always multiples of [`GROUP_ROWS`] (regions never cut a MAC
/// group); columns are unconstrained. Re-exported as
/// `engine::tiling::Rect` for the placement layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rect {
    pub row0: usize,
    pub rows: usize,
    pub col0: usize,
    pub cols: usize,
}

impl Rect {
    /// Whether two rects share any cell.
    pub fn overlaps(&self, o: &Rect) -> bool {
        self.row0 < o.row0 + o.rows
            && o.row0 < self.row0 + self.rows
            && self.col0 < o.col0 + o.cols
            && o.col0 < self.col0 + self.cols
    }
}

/// Which flavor's digitization path to apply to a group's (a, b) counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    Cim1,
    Cim2,
}

impl Flavor {
    /// Group output after the flavor's ADC/subtract path, ideal circuits.
    #[inline]
    pub fn group_output(&self, a: u32, b: u32) -> i32 {
        match self {
            Flavor::Cim1 => (a.min(SAT) as i32) - (b.min(SAT) as i32),
            Flavor::Cim2 => {
                let d = a as i32 - b as i32;
                d.signum() * d.unsigned_abs().min(SAT) as i32
            }
        }
    }

    /// Row-grouping for a full-column dot product: SiTe CiM I asserts 16
    /// *consecutive* rows per cycle; SiTe CiM II asserts one row from each
    /// of the 16 blocks (strided), because the cross-coupling transistors
    /// are shared per block (§IV.3).
    ///
    /// Like every MAC entry point, this rejects row counts that are not a
    /// multiple of [`GROUP_ROWS`]: a partial group has no hardware
    /// equivalent (16 word-lines assert per cycle), so callers must pad
    /// the final group with zero rows instead (zero weights/inputs are
    /// electrically inert and leave every group output unchanged).
    pub fn group_rows(&self, n_rows: usize, cycle: usize) -> Vec<usize> {
        let n_groups = check_grouping(n_rows);
        assert!(
            cycle < n_groups,
            "cycle {cycle} out of range: {n_rows} rows form {n_groups} MAC groups"
        );
        match self {
            Flavor::Cim1 => (cycle * GROUP_ROWS..(cycle + 1) * GROUP_ROWS).collect(),
            Flavor::Cim2 => (0..GROUP_ROWS).map(|blk| blk * n_groups + cycle).collect(),
        }
    }
}

/// Validate a row count against the 16-row grouping and return the number
/// of MAC cycles. Every dot-product path funnels through this so partial
/// final groups are rejected with the same clear error everywhere rather
/// than silently truncated (`n_rows / 16` used to drop tail rows).
#[inline]
pub fn check_grouping(n_rows: usize) -> usize {
    assert!(
        n_rows % GROUP_ROWS == 0,
        "n_rows = {n_rows} is not a multiple of GROUP_ROWS = {GROUP_ROWS}; \
         pad the final MAC group with zero rows (zero weights are inert)"
    );
    n_rows / GROUP_ROWS
}

/// Reference dot product of a full input vector against every column,
/// applying the flavor's grouping + saturation — pure integer math, no
/// circuit models. This is the specification the analog paths, the bit-
/// packed fast path and the Pallas kernel must all agree with.
pub fn dot_ref(storage: &TernaryStorage, inputs: &[Trit], flavor: Flavor) -> Vec<i32> {
    assert_eq!(inputs.len(), storage.n_rows());
    let n_cycles = check_grouping(storage.n_rows());
    let mut out = vec![0i32; storage.n_cols()];
    for cycle in 0..n_cycles {
        let rows = flavor.group_rows(storage.n_rows(), cycle);
        for col in 0..storage.n_cols() {
            let mut a = 0u32;
            let mut b = 0u32;
            for &r in &rows {
                let p = inputs[r] as i32 * storage.read(r, col) as i32;
                if p == 1 {
                    a += 1;
                } else if p == -1 {
                    b += 1;
                }
            }
            out[col] += flavor.group_output(a, b);
        }
    }
    out
}

/// Fast bit-packed equivalent of `dot_ref` for either flavor — the hot
/// path of functional inference and the engine; see benches/array_bench.
pub fn dot_fast(storage: &TernaryStorage, inputs: &[Trit], flavor: Flavor) -> Vec<i32> {
    match flavor {
        Flavor::Cim1 => dot_fast_cim1(storage, inputs),
        Flavor::Cim2 => dot_fast_cim2(storage, inputs),
    }
}

/// Fast bit-packed equivalent of `dot_ref` for `Flavor::Cim1` (consecutive
/// groups align with the packed blocks).
pub fn dot_fast_cim1(storage: &TernaryStorage, inputs: &[Trit]) -> Vec<i32> {
    assert_eq!(inputs.len(), storage.n_rows());
    let n_cycles = check_grouping(storage.n_rows());
    let mut out = vec![0i32; storage.n_cols()];
    for cycle in 0..n_cycles {
        let base = cycle * GROUP_ROWS;
        let (ip, in_) = pack_inputs16(&inputs[base..base + GROUP_ROWS]);
        if ip == 0 && in_ == 0 {
            continue; // all-zero input group: no wordline asserted
        }
        for (col, o) in out.iter_mut().enumerate() {
            let (a, b) = storage.block_ab(base, col, ip, in_);
            *o += Flavor::Cim1.group_output(a, b);
        }
    }
    out
}

/// The cycle-selection bit masks for `Flavor::Cim2`'s strided grouping:
/// `masks[cycle]` has a bit set for every row asserted in that cycle
/// (rows ≡ cycle mod n_groups), in the packed-word layout. These depend
/// only on the row count, so batched GEMMs compute them once.
pub fn cim2_cycle_masks(n_rows: usize) -> Vec<Vec<u64>> {
    let n_groups = check_grouping(n_rows);
    let words = n_rows.div_ceil(64);
    let mut masks = vec![vec![0u64; words]; n_groups];
    for r in 0..n_rows {
        masks[r % n_groups][r / 64] |= 1u64 << (r % 64);
    }
    masks
}

/// Fast bit-packed equivalent of `dot_ref` for `Flavor::Cim2`. The
/// strided groups don't align with 16-bit blocks, so instead of per-block
/// masks we form each column's ±1-product bit-planes once and select each
/// cycle's rows with a precomputed stride mask (see [`cim2_cycle_masks`]).
pub fn dot_fast_cim2(storage: &TernaryStorage, inputs: &[Trit]) -> Vec<i32> {
    let masks = cim2_cycle_masks(storage.n_rows());
    dot_fast_cim2_with_masks(storage, inputs, &masks)
}

/// [`dot_fast_cim2`] with caller-provided cycle masks (batched hot path).
pub fn dot_fast_cim2_with_masks(
    storage: &TernaryStorage,
    inputs: &[Trit],
    masks: &[Vec<u64>],
) -> Vec<i32> {
    assert_eq!(inputs.len(), storage.n_rows());
    let n_cycles = check_grouping(storage.n_rows());
    assert_eq!(masks.len(), n_cycles);
    let wpc = storage.words_per_col();
    let (ip, in_) = pack_inputs_words(inputs);
    let mut out = vec![0i32; storage.n_cols()];
    // Per-column ±1-product planes, reused across cycles.
    let mut plus = vec![0u64; wpc];
    let mut minus = vec![0u64; wpc];
    for (col, o) in out.iter_mut().enumerate() {
        let (wp, wn) = storage.col_words(col);
        for w in 0..wpc {
            plus[w] = (ip[w] & wp[w]) | (in_[w] & wn[w]);
            minus[w] = (ip[w] & wn[w]) | (in_[w] & wp[w]);
        }
        for mask in masks {
            let mut a = 0u32;
            let mut b = 0u32;
            for w in 0..wpc {
                a += (plus[w] & mask[w]).count_ones();
                b += (minus[w] & mask[w]).count_ones();
            }
            *o += Flavor::Cim2.group_output(a, b);
        }
    }
    out
}

/// Batched fast path: `m` input vectors (row-major, each `n_rows` long)
/// against every column → row-major `m × n_cols` outputs. Amortizes the
/// CiM II stride-mask construction across the batch.
pub fn dot_fast_batch(storage: &TernaryStorage, inputs: &[Trit], m: usize, flavor: Flavor) -> Vec<i32> {
    let n_rows = storage.n_rows();
    assert_eq!(inputs.len(), m * n_rows, "batch of {m} vectors × {n_rows} rows");
    let mut out = Vec::with_capacity(m * storage.n_cols());
    match flavor {
        Flavor::Cim1 => {
            for r in 0..m {
                out.extend(dot_fast_cim1(storage, &inputs[r * n_rows..(r + 1) * n_rows]));
            }
        }
        Flavor::Cim2 => {
            let masks = cim2_cycle_masks(n_rows);
            for r in 0..m {
                out.extend(dot_fast_cim2_with_masks(
                    storage,
                    &inputs[r * n_rows..(r + 1) * n_rows],
                    &masks,
                ));
            }
        }
    }
    out
}

/// Exact (no saturation) dot products — the near-memory baseline's
/// digital MAC and the accuracy reference.
pub fn dot_exact(storage: &TernaryStorage, inputs: &[Trit]) -> Vec<i64> {
    (0..storage.n_cols()).map(|c| storage.column_dot_exact(c, inputs)).collect()
}

/// Validate a region request against the storage and the batch shape.
/// All three region kernels funnel through this so violations fail with
/// the same message everywhere.
fn check_region(storage: &TernaryStorage, rect: &Rect, inputs_len: usize, m: usize) {
    assert!(m > 0, "empty batch (m = 0)");
    assert!(rect.rows > 0 && rect.cols > 0, "empty region {rect:?}");
    assert!(
        rect.row0 % GROUP_ROWS == 0 && rect.rows % GROUP_ROWS == 0,
        "region rows must be {GROUP_ROWS}-aligned: {rect:?}"
    );
    assert!(
        rect.row0 + rect.rows <= storage.n_rows() && rect.col0 + rect.cols <= storage.n_cols(),
        "region {rect:?} exceeds the {}x{} array",
        storage.n_rows(),
        storage.n_cols()
    );
    assert_eq!(
        inputs_len,
        m * rect.rows,
        "batch of {m} region vectors x {} rows",
        rect.rows
    );
}

/// Region-scoped batched MAC for `Flavor::Cim1`: `m` region-local input
/// vectors (row-major, each `rect.rows` long — `inputs[j]` drives array
/// row `rect.row0 + j`) against the region's columns → row-major
/// `m × rect.cols` outputs. Bit-identical to the full-array
/// [`dot_fast_batch`] on zero-padded inputs, sliced to the region's
/// columns, at a cost proportional to the region: consecutive groups
/// align with the packed 16-bit blocks, so only the region's
/// `rect.rows / 16` cycles run, over only `rect.cols` columns.
pub fn dot_region_cim1(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * rect.cols];
    dot_region_cim1_into(storage, rect, inputs, m, &mut out);
    out
}

/// [`dot_region_cim1`] into a caller-provided `m × rect.cols` buffer
/// (overwritten): the executor's scratch-reuse path — a long-lived
/// worker keeps one partial-sum buffer instead of allocating a fresh
/// output per work item.
pub fn dot_region_cim1_into(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
    out: &mut [i32],
) {
    check_region(storage, rect, inputs.len(), m);
    assert_eq!(out.len(), m * rect.cols, "output buffer must be m × rect.cols");
    out.fill(0);
    for v in 0..m {
        let xv = &inputs[v * rect.rows..(v + 1) * rect.rows];
        let o = &mut out[v * rect.cols..(v + 1) * rect.cols];
        for g in (0..rect.rows).step_by(GROUP_ROWS) {
            let (ip, in_) = pack_inputs16(&xv[g..g + GROUP_ROWS]);
            if ip == 0 && in_ == 0 {
                continue; // all-zero input group: no wordline asserted
            }
            let base = rect.row0 + g;
            for (c, oc) in o.iter_mut().enumerate() {
                let (a, b) = storage.block_ab(base, rect.col0 + c, ip, in_);
                *oc += Flavor::Cim1.group_output(a, b);
            }
        }
    }
}

/// Region-scoped batched MAC for `Flavor::Cim2` (same surface as
/// [`dot_region_cim1`]). The strided grouping spans the whole array, so
/// the *full-array* cycle masks are kept — saturation happens in exactly
/// the groups the hardware would digitize — but each mask is restricted
/// to the region's word span and cycles that assert no region row are
/// skipped entirely (their counts are zero: rows outside the region see
/// zero inputs). Per-column plane construction and per-cycle popcounts
/// then cost `O(span words)` instead of `O(all words)`.
pub fn dot_region_cim2(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * rect.cols];
    dot_region_cim2_into(storage, rect, inputs, m, &mut out);
    out
}

/// [`dot_region_cim2`] into a caller-provided `m × rect.cols` buffer
/// (overwritten). Builds the restricted stride masks and bit-plane
/// buffers per call; the executor's steady-state path uses
/// [`dot_region_cim2_scratch_into`] instead, which caches both in a
/// per-worker [`RegionScratch`].
pub fn dot_region_cim2_into(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
    out: &mut [i32],
) {
    check_region(storage, rect, inputs.len(), m);
    let masks = Cim2RegionMasks::build(storage.n_rows(), rect.row0, rect.rows);
    let mut bufs = Cim2PlaneBufs::default();
    cim2_region_kernel(storage, rect, inputs, m, &masks, &mut bufs, out);
}

/// [`dot_region_cim2`] against a per-worker [`RegionScratch`]: the
/// restricted stride masks are computed once per (row geometry, region
/// row span) and cached, and the ±1 bit planes reuse the scratch's
/// buffers — the steady-state call performs zero heap allocations.
pub fn dot_region_cim2_scratch_into(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
    scratch: &mut RegionScratch,
    out: &mut [i32],
) {
    check_region(storage, rect, inputs.len(), m);
    let key = (storage.n_rows(), rect.row0, rect.rows);
    let (masks, bufs) = scratch.masks_and_bufs(key);
    cim2_region_kernel(storage, rect, inputs, m, masks, bufs, out);
}

/// Entries retained in [`RegionScratch`]'s mask cache. Keys are (array
/// row count, region row start, region row count) — a worker's
/// steady-state working set is one entry per distinct placed region
/// row-span it executes, typically a handful. At capacity the cache
/// evicts the single least-recently-used entry, so a pathological churn
/// of region shapes costs one rebuild per new shape instead of
/// flushing the whole resident working set.
const REGION_MASK_CACHE_CAP: usize = 256;

/// One cached mask set plus its last-use stamp for LRU eviction.
struct MaskEntry {
    last_use: u64,
    masks: Cim2RegionMasks,
}

/// Per-worker scratch for the region kernels: the CiM II restricted
/// stride-mask cache plus reusable bit-plane buffers. Owned by each
/// executor worker (see `engine::exec::WorkerScratch`); the kernels
/// never share one across threads.
pub struct RegionScratch {
    /// (n_rows, row0, rows) → restricted cycle masks. The masks depend
    /// only on the array's row count and the region's *row* span — not
    /// its columns and not the array's contents — so one entry serves
    /// every same-shaped placement on every array.
    cim2_masks: std::collections::HashMap<(usize, usize, usize), MaskEntry>,
    bufs: Cim2PlaneBufs,
    /// Mask-cache capacity; [`REGION_MASK_CACHE_CAP`] by default.
    cap: usize,
    /// Monotonic access stamp for the LRU policy.
    clock: u64,
    /// Calls served from the cache (no mask rebuild).
    mask_hits: u64,
}

impl Default for RegionScratch {
    fn default() -> RegionScratch {
        RegionScratch::with_mask_cap(REGION_MASK_CACHE_CAP)
    }
}

impl RegionScratch {
    /// Scratch with a custom mask-cache capacity (tests exercise the
    /// eviction path with tiny caps; production code uses `default()`).
    pub fn with_mask_cap(cap: usize) -> RegionScratch {
        RegionScratch {
            cim2_masks: std::collections::HashMap::new(),
            bufs: Cim2PlaneBufs::default(),
            cap: cap.max(1),
            clock: 0,
            mask_hits: 0,
        }
    }

    /// Cached mask entries (observability for tests).
    pub fn cached_masks(&self) -> usize {
        self.cim2_masks.len()
    }

    /// Kernel calls served without rebuilding masks (observability for
    /// tests).
    pub fn mask_hits(&self) -> u64 {
        self.mask_hits
    }

    /// The cache policy in one place: return `key`'s masks (building
    /// them on a miss, evicting the least-recently-used entry when at
    /// capacity) alongside the reusable plane buffers.
    fn masks_and_bufs(
        &mut self,
        key: (usize, usize, usize),
    ) -> (&Cim2RegionMasks, &mut Cim2PlaneBufs) {
        self.clock += 1;
        if let Some(e) = self.cim2_masks.get_mut(&key) {
            e.last_use = self.clock;
            self.mask_hits += 1;
        } else {
            if self.cim2_masks.len() >= self.cap {
                let lru = self
                    .cim2_masks
                    .iter()
                    .min_by_key(|(_, e)| e.last_use)
                    .map(|(&k, _)| k)
                    .expect("cap >= 1, so a full cache has an LRU entry");
                self.cim2_masks.remove(&lru);
            }
            let masks = Cim2RegionMasks::build(key.0, key.1, key.2);
            self.cim2_masks.insert(key, MaskEntry { last_use: self.clock, masks });
        }
        (&self.cim2_masks[&key].masks, &mut self.bufs)
    }
}

/// The full-array CiM II stride masks restricted to one region's word
/// span, precomputed: cycles that assert no region row contribute
/// `group_output(0, 0) = 0` and are dropped.
pub struct Cim2RegionMasks {
    /// First packed word of the span (`row0 / 64`).
    w0: usize,
    /// Words in the span.
    span: usize,
    /// Kept cycles' masks, flattened `n_kept × span` row-major.
    masks: Vec<u64>,
}

impl Cim2RegionMasks {
    fn build(n_rows: usize, row0: usize, rows: usize) -> Cim2RegionMasks {
        let w0 = row0 / 64;
        let w1 = (row0 + rows).div_ceil(64);
        let span = w1 - w0;
        // The region's rows as a bit mask over the span words (span
        // words can cover non-region rows when the region is not
        // 64-aligned).
        let mut range = vec![0u64; span];
        for r in row0..row0 + rows {
            range[r / 64 - w0] |= 1u64 << (r % 64);
        }
        let mut masks = Vec::new();
        for cm in cim2_cycle_masks(n_rows) {
            let mm: Vec<u64> = (0..span).map(|wi| cm[w0 + wi] & range[wi]).collect();
            if mm.iter().any(|&w| w != 0) {
                masks.extend_from_slice(&mm);
            }
        }
        Cim2RegionMasks { w0, span, masks }
    }
}

/// Reusable ±1-product plane buffers for the CiM II region kernel.
#[derive(Default)]
struct Cim2PlaneBufs {
    ip: Vec<u64>,
    in_: Vec<u64>,
    plus: Vec<u64>,
    minus: Vec<u64>,
}

/// The shared CiM II region kernel body: both the per-call and the
/// scratch-cached entry points funnel here.
fn cim2_region_kernel(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
    rm: &Cim2RegionMasks,
    bufs: &mut Cim2PlaneBufs,
    out: &mut [i32],
) {
    check_region(storage, rect, inputs.len(), m);
    assert_eq!(out.len(), m * rect.cols, "output buffer must be m × rect.cols");
    out.fill(0);
    let (w0, span) = (rm.w0, rm.span);
    let w1 = w0 + span;
    bufs.ip.resize(span, 0);
    bufs.in_.resize(span, 0);
    bufs.plus.resize(span, 0);
    bufs.minus.resize(span, 0);
    let (ip, in_, plus, minus) = (&mut bufs.ip, &mut bufs.in_, &mut bufs.plus, &mut bufs.minus);
    for v in 0..m {
        let xv = &inputs[v * rect.rows..(v + 1) * rect.rows];
        ip.fill(0);
        in_.fill(0);
        for (j, &i) in xv.iter().enumerate() {
            let r = rect.row0 + j;
            match i {
                1 => ip[r / 64 - w0] |= 1u64 << (r % 64),
                -1 => in_[r / 64 - w0] |= 1u64 << (r % 64),
                _ => {}
            }
        }
        for c in 0..rect.cols {
            let (wp, wn) = storage.col_words(rect.col0 + c);
            let (wp, wn) = (&wp[w0..w1], &wn[w0..w1]);
            for wi in 0..span {
                plus[wi] = (ip[wi] & wp[wi]) | (in_[wi] & wn[wi]);
                minus[wi] = (ip[wi] & wn[wi]) | (in_[wi] & wp[wi]);
            }
            let mut acc = 0i32;
            for mask in rm.masks.chunks_exact(span) {
                let mut a = 0u32;
                let mut b = 0u32;
                for wi in 0..span {
                    a += (plus[wi] & mask[wi]).count_ones();
                    b += (minus[wi] & mask[wi]).count_ones();
                }
                acc += Flavor::Cim2.group_output(a, b);
            }
            out[v * rect.cols + c] = acc;
        }
    }
}

/// Region-scoped exact batched MAC — the near-memory baseline's region
/// path (same surface as [`dot_region_cim1`], no saturation). Reads only
/// the region's rows and columns; outputs are bounded by `rect.rows`, so
/// `i32` is exact.
pub fn dot_region_exact(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; m * rect.cols];
    dot_region_exact_into(storage, rect, inputs, m, &mut out);
    out
}

/// [`dot_region_exact`] into a caller-provided `m × rect.cols` buffer
/// (overwritten) — allocation-free like [`dot_region_cim1_into`].
pub fn dot_region_exact_into(
    storage: &TernaryStorage,
    rect: &Rect,
    inputs: &[Trit],
    m: usize,
    out: &mut [i32],
) {
    check_region(storage, rect, inputs.len(), m);
    assert_eq!(out.len(), m * rect.cols, "output buffer must be m × rect.cols");
    out.fill(0);
    for v in 0..m {
        let xv = &inputs[v * rect.rows..(v + 1) * rect.rows];
        for c in 0..rect.cols {
            let mut acc = 0i32;
            for (j, &i) in xv.iter().enumerate() {
                if i != 0 {
                    acc += i as i32 * storage.read(rect.row0 + j, rect.col0 + c) as i32;
                }
            }
            out[v * rect.cols + c] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_setup(seed: u64, rows: usize, cols: usize, sparsity: f64) -> (TernaryStorage, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let mut s = TernaryStorage::new(rows, cols);
        s.write_matrix(&rng.ternary_vec(rows * cols, sparsity));
        let inputs = rng.ternary_vec(rows, sparsity);
        (s, inputs)
    }

    #[test]
    fn group_output_saturates_both_flavors() {
        assert_eq!(Flavor::Cim1.group_output(10, 9), 0); // both clamp to 8
        assert_eq!(Flavor::Cim2.group_output(10, 9), 1); // diff clamps after
        assert_eq!(Flavor::Cim1.group_output(16, 0), 8);
        assert_eq!(Flavor::Cim2.group_output(16, 0), 8);
        assert_eq!(Flavor::Cim1.group_output(0, 12), -8);
        assert_eq!(Flavor::Cim2.group_output(3, 2), 1);
    }

    #[test]
    fn groupings_partition_rows() {
        for flavor in [Flavor::Cim1, Flavor::Cim2] {
            let mut seen = vec![false; 256];
            for cycle in 0..16 {
                for r in flavor.group_rows(256, cycle) {
                    assert!(!seen[r], "{flavor:?}: row {r} grouped twice");
                    seen[r] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{flavor:?}: rows missing");
        }
    }

    #[test]
    fn cim2_groups_are_strided() {
        let rows = Flavor::Cim2.group_rows(256, 0);
        assert_eq!(rows[0], 0);
        assert_eq!(rows[1], 16);
        assert_eq!(rows[15], 240);
    }

    #[test]
    fn fast_path_matches_reference() {
        let (s, inputs) = random_setup(42, 256, 64, 0.45);
        assert_eq!(dot_fast_cim1(&s, &inputs), dot_ref(&s, &inputs, Flavor::Cim1));
    }

    #[test]
    fn fast_path_matches_reference_both_flavors_varied_shapes() {
        for (seed, rows, cols, pz) in
            [(1u64, 16usize, 8usize, 0.5), (2, 64, 32, 0.3), (3, 256, 256, 0.5), (4, 320, 17, 0.7)]
        {
            let (s, inputs) = random_setup(seed, rows, cols, pz);
            for flavor in [Flavor::Cim1, Flavor::Cim2] {
                assert_eq!(
                    dot_fast(&s, &inputs, flavor),
                    dot_ref(&s, &inputs, flavor),
                    "{flavor:?} {rows}x{cols}"
                );
            }
        }
    }

    #[test]
    fn batched_fast_path_matches_per_row() {
        let mut rng = Rng::new(9);
        let mut s = TernaryStorage::new(128, 48);
        s.write_matrix(&rng.ternary_vec(128 * 48, 0.5));
        let m = 5;
        let batch = rng.ternary_vec(m * 128, 0.5);
        for flavor in [Flavor::Cim1, Flavor::Cim2] {
            let got = dot_fast_batch(&s, &batch, m, flavor);
            for r in 0..m {
                assert_eq!(
                    &got[r * 48..(r + 1) * 48],
                    dot_ref(&s, &batch[r * 128..(r + 1) * 128], flavor).as_slice(),
                    "{flavor:?} row {r}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of GROUP_ROWS")]
    fn partial_groups_rejected_not_truncated() {
        // 40 inputs against a notional 40-row grouping must be rejected
        // loudly (the old code silently computed 2 of 2.5 groups).
        Flavor::Cim1.group_rows(40, 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_cycle_rejected() {
        Flavor::Cim2.group_rows(64, 4);
    }

    #[test]
    fn sparse_inputs_rarely_saturate() {
        // At the paper's operating sparsity, the saturating dot product
        // should agree with the exact one almost everywhere.
        let (s, inputs) = random_setup(7, 256, 128, 0.65);
        let sat = dot_ref(&s, &inputs, Flavor::Cim1);
        let exact = dot_exact(&s, &inputs);
        let mismatches = sat
            .iter()
            .zip(&exact)
            .filter(|&(&a, &e)| a as i64 != e)
            .count();
        assert!(mismatches < 8, "saturation distorted {mismatches}/128 columns");
    }

    #[test]
    fn dense_worst_case_saturates() {
        // All +1 weights, all +1 inputs: every group pegs at +8.
        let mut s = TernaryStorage::new(256, 4);
        s.write_matrix(&vec![1i8; 256 * 4]);
        let inputs = vec![1i8; 256];
        for flavor in [Flavor::Cim1, Flavor::Cim2] {
            let out = dot_ref(&s, &inputs, flavor);
            assert!(out.iter().all(|&o| o == 16 * 8), "{flavor:?}: {out:?}");
        }
    }

    /// The region-kernel specification, in miniature: pad region-local
    /// inputs to the full array, run the full batched MAC, slice the
    /// region's columns.
    fn full_array_region_ref(
        s: &TernaryStorage,
        rect: &Rect,
        inputs: &[Trit],
        m: usize,
        flavor: Option<Flavor>,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(m * rect.cols);
        for v in 0..m {
            let mut padded = vec![0i8; s.n_rows()];
            padded[rect.row0..rect.row0 + rect.rows]
                .copy_from_slice(&inputs[v * rect.rows..(v + 1) * rect.rows]);
            let full: Vec<i32> = match flavor {
                Some(f) => dot_ref(s, &padded, f),
                None => dot_exact(s, &padded).into_iter().map(|x| x as i32).collect(),
            };
            out.extend_from_slice(&full[rect.col0..rect.col0 + rect.cols]);
        }
        out
    }

    #[test]
    fn region_kernels_match_full_array_slice() {
        let mut rng = Rng::new(21);
        let (s, _) = random_setup(21, 256, 48, 0.4);
        let m = 3;
        for rect in [
            Rect { row0: 0, rows: 256, col0: 0, cols: 48 }, // whole array
            Rect { row0: 64, rows: 64, col0: 7, cols: 13 }, // unaligned cols
            Rect { row0: 240, rows: 16, col0: 47, cols: 1 }, // last group/col
            Rect { row0: 16, rows: 208, col0: 0, cols: 48 }, // unaligned words
        ] {
            let inputs = rng.ternary_vec(m * rect.rows, 0.4);
            assert_eq!(
                dot_region_cim1(&s, &rect, &inputs, m),
                full_array_region_ref(&s, &rect, &inputs, m, Some(Flavor::Cim1)),
                "cim1 {rect:?}"
            );
            assert_eq!(
                dot_region_cim2(&s, &rect, &inputs, m),
                full_array_region_ref(&s, &rect, &inputs, m, Some(Flavor::Cim2)),
                "cim2 {rect:?}"
            );
            assert_eq!(
                dot_region_exact(&s, &rect, &inputs, m),
                full_array_region_ref(&s, &rect, &inputs, m, None),
                "exact {rect:?}"
            );
        }
    }

    #[test]
    fn region_into_kernels_overwrite_dirty_buffers() {
        // The `_into` variants are the executor's scratch-reuse path: a
        // worker's buffer arrives full of the previous item's partials
        // and must be fully overwritten, not accumulated into.
        let (s, _) = random_setup(27, 128, 24, 0.5);
        let mut rng = Rng::new(28);
        let m = 2;
        let rect = Rect { row0: 16, rows: 64, col0: 3, cols: 9 };
        let inputs = rng.ternary_vec(m * rect.rows, 0.5);
        let mut buf = vec![i32::MAX; m * rect.cols];
        dot_region_cim1_into(&s, &rect, &inputs, m, &mut buf);
        assert_eq!(buf, dot_region_cim1(&s, &rect, &inputs, m));
        buf.fill(-7);
        dot_region_cim2_into(&s, &rect, &inputs, m, &mut buf);
        assert_eq!(buf, dot_region_cim2(&s, &rect, &inputs, m));
        buf.fill(123);
        dot_region_exact_into(&s, &rect, &inputs, m, &mut buf);
        assert_eq!(buf, dot_region_exact(&s, &rect, &inputs, m));
    }

    #[test]
    fn cim2_region_keeps_full_array_saturation_grouping() {
        // A dense +1 region: CiM II groups stride over the whole array,
        // so a 32-row region of a 64-row array spreads its rows across
        // all 4 cycles (8 rows each, no saturation), while a local
        // 2-cycle grouping would have pegged both groups at +8.
        let mut s = TernaryStorage::new(64, 2);
        s.write_matrix(&vec![1i8; 64 * 2]);
        let rect = Rect { row0: 0, rows: 32, col0: 0, cols: 2 };
        let inputs = vec![1i8; 32];
        let got = dot_region_cim2(&s, &rect, &inputs, 1);
        assert_eq!(got, vec![32, 32], "4 cycles x 8 unsaturated counts");
        // And it matches the padded full-array reference, which is the
        // actual contract.
        assert_eq!(got, full_array_region_ref(&s, &rect, &inputs, 1, Some(Flavor::Cim2)));
    }

    #[test]
    #[should_panic(expected = "region rows must be")]
    fn region_rejects_unaligned_rows() {
        let s = TernaryStorage::new(64, 4);
        let rect = Rect { row0: 8, rows: 16, col0: 0, cols: 4 };
        dot_region_cim1(&s, &rect, &[0i8; 16], 1);
    }

    #[test]
    #[should_panic(expected = "exceeds the")]
    fn region_rejects_out_of_bounds() {
        let s = TernaryStorage::new(64, 4);
        let rect = Rect { row0: 48, rows: 32, col0: 0, cols: 4 };
        dot_region_cim2(&s, &rect, &[0i8; 32], 1);
    }

    #[test]
    fn cim2_scratch_path_matches_per_call_and_caches_masks() {
        let (s, _) = random_setup(33, 256, 48, 0.4);
        let mut rng = Rng::new(34);
        let mut scratch = RegionScratch::default();
        let m = 3;
        let rects = [
            Rect { row0: 0, rows: 256, col0: 0, cols: 48 },
            Rect { row0: 64, rows: 64, col0: 7, cols: 13 },
            Rect { row0: 240, rows: 16, col0: 47, cols: 1 },
            Rect { row0: 16, rows: 208, col0: 0, cols: 48 },
            // Same row span as the second rect, different columns: must
            // share its cached masks, not add an entry.
            Rect { row0: 64, rows: 64, col0: 20, cols: 5 },
        ];
        for (pass, rect) in rects.iter().enumerate() {
            let inputs = rng.ternary_vec(m * rect.rows, 0.4);
            let mut got = vec![i32::MIN; m * rect.cols]; // dirty scratch buffer
            dot_region_cim2_scratch_into(&s, rect, &inputs, m, &mut scratch, &mut got);
            assert_eq!(got, dot_region_cim2(&s, rect, &inputs, m), "pass {pass} {rect:?}");
        }
        assert_eq!(scratch.cached_masks(), 4, "one entry per distinct row span");
        assert_eq!(scratch.mask_hits(), 1, "the span-sharing rect is the only first-pass hit");
        // Steady state: repeating the working set adds no entries and
        // every call is a cache hit.
        for rect in &rects {
            let inputs = rng.ternary_vec(m * rect.rows, 0.4);
            let mut got = vec![0i32; m * rect.cols];
            dot_region_cim2_scratch_into(&s, rect, &inputs, m, &mut scratch, &mut got);
            assert_eq!(got, dot_region_cim2(&s, rect, &inputs, m));
        }
        assert_eq!(scratch.cached_masks(), 4);
        assert_eq!(scratch.mask_hits(), 6);
    }

    #[test]
    fn mask_cache_evicts_one_lru_entry_not_the_working_set() {
        let (s, _) = random_setup(35, 256, 8, 0.4);
        let mut rng = Rng::new(36);
        let mut scratch = RegionScratch::with_mask_cap(2);
        let m = 2;
        let rect_at = |row0: usize| Rect { row0, rows: 64, col0: 0, cols: 8 };
        let run = |scratch: &mut RegionScratch, rng: &mut Rng, row0: usize| {
            let rect = rect_at(row0);
            let inputs = rng.ternary_vec(m * rect.rows, 0.4);
            let mut got = vec![i32::MIN; m * rect.cols];
            dot_region_cim2_scratch_into(&s, &rect, &inputs, m, scratch, &mut got);
            assert_eq!(got, dot_region_cim2(&s, &rect, &inputs, m), "row0 {row0}");
        };
        let (a, b, c) = (0, 64, 128);
        run(&mut scratch, &mut rng, a);
        run(&mut scratch, &mut rng, b);
        assert_eq!((scratch.cached_masks(), scratch.mask_hits()), (2, 0));
        run(&mut scratch, &mut rng, b); // bump b: a is now the LRU entry
        assert_eq!(scratch.mask_hits(), 1);
        run(&mut scratch, &mut rng, c); // at cap: evicts a alone
        assert_eq!((scratch.cached_masks(), scratch.mask_hits()), (2, 1));
        // The rest of the working set survives the eviction — the old
        // clear-wholesale policy would miss here.
        run(&mut scratch, &mut rng, b);
        assert_eq!(scratch.mask_hits(), 2);
        run(&mut scratch, &mut rng, a); // miss; evicts c (b was just used)
        assert_eq!((scratch.cached_masks(), scratch.mask_hits()), (2, 2));
        run(&mut scratch, &mut rng, b); // still resident
        run(&mut scratch, &mut rng, c); // miss again
        assert_eq!((scratch.cached_masks(), scratch.mask_hits()), (2, 3));
    }

    #[test]
    fn rect_overlap_is_symmetric_and_strict() {
        let a = Rect { row0: 0, rows: 32, col0: 0, cols: 16 };
        let b = Rect { row0: 16, rows: 32, col0: 8, cols: 16 };
        let c = Rect { row0: 32, rows: 16, col0: 0, cols: 16 }; // touches a, no overlap
        assert!(a.overlaps(&b) && b.overlaps(&a));
        assert!(!a.overlaps(&c) && !c.overlaps(&a));
        let d = Rect { row0: 0, rows: 32, col0: 16, cols: 4 }; // adjacent columns
        assert!(!a.overlaps(&d));
    }

    #[test]
    fn flavors_agree_except_double_saturation() {
        let (s, inputs) = random_setup(11, 256, 256, 0.5);
        let o1 = dot_ref(&s, &inputs, Flavor::Cim1);
        let o2 = dot_ref(&s, &inputs, Flavor::Cim2);
        // Different groupings/saturation make tiny differences, but the
        // results must be strongly correlated.
        let close = o1.iter().zip(&o2).filter(|&(&a, &b)| (a - b).abs() <= 2).count();
        assert!(close > 240, "only {close}/256 columns close");
    }
}
