//! Signed-ternary encodings and the scalar-product truth tables
//! (paper Fig 3 for SiTe CiM I, Fig 5(b–e) for SiTe CiM II).
//!
//! Differential weight encoding (both flavors):
//!   W = 0  → (M1, M2) = (0, 0)
//!   W = +1 → (M1, M2) = (1, 0)
//!   W = −1 → (M1, M2) = (0, 1)
//! (M1 = M2 = 1 is unused/illegal.)
//!
//! Input encoding, SiTe CiM I (RWL1, RWL2):
//!   I = 0  → (0, 0);  I = +1 → (VDD, 0);  I = −1 → (0, VDD)
//! Input encoding, SiTe CiM II (RWL, RWL_t1, RWL_t2):
//!   I = 0  → (0, 0, 0);  I = +1 → (VDD, VDD, 0);  I = −1 → (VDD, 0, VDD)
//!
//! Output encoding (voltage sensing): O = +1 ⇔ RBL1 discharges,
//! O = −1 ⇔ RBL2 discharges, O = 0 ⇔ neither.

/// A signed ternary value. Stored as i8 ∈ {−1, 0, +1} throughout the
/// crate; this module centralizes validation and encode/decode.
pub type Trit = i8;

/// Validate a trit.
pub fn is_trit(x: i8) -> bool {
    (-1..=1).contains(&x)
}

/// Weight → (M1, M2) differential encoding (Fig 3(a)).
pub fn encode_weight(w: Trit) -> (bool, bool) {
    debug_assert!(is_trit(w));
    match w {
        1 => (true, false),
        -1 => (false, true),
        _ => (false, false),
    }
}

/// (M1, M2) → weight. `(true, true)` is an illegal cell state; we surface
/// it as an error so array tests can assert it never occurs.
pub fn decode_weight(m1: bool, m2: bool) -> Result<Trit, IllegalCellState> {
    match (m1, m2) {
        (false, false) => Ok(0),
        (true, false) => Ok(1),
        (false, true) => Ok(-1),
        (true, true) => Err(IllegalCellState),
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IllegalCellState;

impl std::fmt::Display for IllegalCellState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal ternary cell state M1=M2=1")
    }
}

impl std::error::Error for IllegalCellState {}

/// SiTe CiM I input → (RWL1, RWL2) levels (Fig 3(b)).
pub fn encode_input_cim1(i: Trit) -> (bool, bool) {
    debug_assert!(is_trit(i));
    match i {
        1 => (true, false),
        -1 => (false, true),
        _ => (false, false),
    }
}

/// SiTe CiM II input → (RWL, RWL_t1, RWL_t2) levels (Fig 5(c)).
pub fn encode_input_cim2(i: Trit) -> (bool, bool, bool) {
    debug_assert!(is_trit(i));
    match i {
        1 => (true, true, false),
        -1 => (true, false, true),
        _ => (false, false, false),
    }
}

/// Which RBL (if any) the cell pulls down in SiTe CiM I, given the input
/// encoding — the electrical truth table behind O = I·W (Fig 3(c–d)).
/// Returns (discharges_rbl1, discharges_rbl2).
pub fn rbl_pulldown_cim1(i: Trit, w: Trit) -> (bool, bool) {
    let (rwl1, rwl2) = encode_input_cim1(i);
    let (m1, m2) = encode_weight(w);
    // RWL1 asserts AX1 (M1→RBL1) and AX2 (M2→RBL2): straight coupling.
    // RWL2 asserts AX3 (M1→RBL2) and AX4 (M2→RBL1): cross coupling.
    let rbl1 = (rwl1 && m1) || (rwl2 && m2);
    let rbl2 = (rwl1 && m2) || (rwl2 && m1);
    (rbl1, rbl2)
}

/// The same for SiTe CiM II: which RBL receives the LRS current
/// (Fig 5(e)). RWL gates the cell onto the LRBLs; RWL_t1 couples straight
/// (LRBL1→RBL1, LRBL2→RBL2), RWL_t2 couples crossed.
pub fn rbl_current_cim2(i: Trit, w: Trit) -> (bool, bool) {
    let (rwl, t1, t2) = encode_input_cim2(i);
    let (m1, m2) = encode_weight(w);
    let lrbl1 = rwl && m1;
    let lrbl2 = rwl && m2;
    let rbl1 = (t1 && lrbl1) || (t2 && lrbl2);
    let rbl2 = (t1 && lrbl2) || (t2 && lrbl1);
    (rbl1, rbl2)
}

/// Decode a scalar product from the RBL pair (Fig 3(c)).
pub fn decode_output(rbl1: bool, rbl2: bool) -> Trit {
    match (rbl1, rbl2) {
        (true, false) => 1,
        (false, true) => -1,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 3(d): the full 9-entry ternary multiplication truth table must
    /// emerge from the SiTe CiM I cell's electrical behaviour.
    #[test]
    fn cim1_truth_table_is_ternary_product() {
        for i in [-1i8, 0, 1] {
            for w in [-1i8, 0, 1] {
                let (r1, r2) = rbl_pulldown_cim1(i, w);
                assert_eq!(decode_output(r1, r2), i * w, "I={i} W={w}");
                assert!(!(r1 && r2), "both RBLs discharged for I={i} W={w}");
            }
        }
    }

    /// Fig 5(e): same for SiTe CiM II's current steering.
    #[test]
    fn cim2_truth_table_is_ternary_product() {
        for i in [-1i8, 0, 1] {
            for w in [-1i8, 0, 1] {
                let (r1, r2) = rbl_current_cim2(i, w);
                assert_eq!(decode_output(r1, r2), i * w, "I={i} W={w}");
                assert!(!(r1 && r2));
            }
        }
    }

    #[test]
    fn weight_encode_decode_roundtrip() {
        for w in [-1i8, 0, 1] {
            let (m1, m2) = encode_weight(w);
            assert_eq!(decode_weight(m1, m2).unwrap(), w);
        }
        assert!(decode_weight(true, true).is_err());
    }

    #[test]
    fn input_zero_deasserts_everything() {
        assert_eq!(encode_input_cim1(0), (false, false));
        assert_eq!(encode_input_cim2(0), (false, false, false));
    }

    #[test]
    fn read_uses_plus_one_encoding() {
        // Reading a row = applying I = +1 (§III.1.b.i: "identical to
        // reading out the weight value").
        for w in [-1i8, 0, 1] {
            let (r1, r2) = rbl_pulldown_cim1(1, w);
            assert_eq!(decode_output(r1, r2), w);
        }
    }
}
