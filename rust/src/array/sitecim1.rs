//! SiTe CiM I: cross-coupled bit-cells, voltage sensing (paper §III).
//!
//! A 256×256 ternary array. Each ternary cell = two bit-cells (M1, M2)
//! plus cross-coupling access transistors AX3/AX4 on a second read
//! word-line. MAC cycles assert 16 rows; each column's two RBLs develop
//! `a`·δ and `b`·δ discharges, two 3-bit flash ADCs digitize them and a
//! digital subtractor produces the signed partial output.
//!
//! Two simulation fidelities:
//! - the [`CimArray`] digital-ideal surface (`dot` / `mac_cycle`, bit-
//!   packed fast path) — exactly the saturating semantics of
//!   `mac::Flavor::Cim1`;
//! - `mac_cycle_analog`: runs the calibrated bit-line discharge ladder +
//!   (optionally varied) ADC references — the Monte-Carlo error path.

use super::area::Design;
use super::cim::CimArray;
use super::encoding::Trit;
use super::mac::GROUP_ROWS;
use super::storage::{pack_inputs16, TernaryStorage};
use crate::circuit::adc::VoltageAdc;
use crate::circuit::bitline::VoltageBitline;
use crate::device::{Tech, TechParams};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SiTeCim1Array {
    storage: TernaryStorage,
    pub params: TechParams,
    pub bitline: VoltageBitline,
    adc: VoltageAdc,
}

impl SiTeCim1Array {
    /// The paper's array: 256×256 ternary cells.
    pub fn new(tech: Tech) -> SiTeCim1Array {
        Self::with_dims(tech, 256, 256)
    }

    pub fn with_dims(tech: Tech, n_rows: usize, n_cols: usize) -> SiTeCim1Array {
        let params = TechParams::new(tech);
        let bitline = VoltageBitline::new(params.vdd);
        let adc = VoltageAdc::ideal(&bitline);
        SiTeCim1Array { storage: TernaryStorage::new(n_rows, n_cols), params, bitline, adc }
    }

    /// One MAC cycle through the analog models: RBL voltage ladder + ADC
    /// (pass an ADC built with `VoltageAdc::with_variation` for MC runs).
    /// `row_base` is the first row of the 16-row consecutive group.
    pub fn mac_cycle_analog(
        &self,
        row_base: usize,
        inputs: &[Trit],
        adc: Option<&VoltageAdc>,
    ) -> Vec<i32> {
        assert_eq!(inputs.len(), GROUP_ROWS);
        assert!(row_base % GROUP_ROWS == 0);
        let adc = adc.unwrap_or(&self.adc);
        let (ip, in_) = pack_inputs16(inputs);
        (0..self.storage.n_cols())
            .map(|c| {
                let (a, b) = self.storage.block_ab(row_base, c, ip, in_);
                // Physical levels after a/b simultaneous discharges.
                let v1 = self.bitline.v_after(a as usize);
                let v2 = self.bitline.v_after(b as usize);
                adc.quantize(v1) as i32 - adc.quantize(v2) as i32
            })
            .collect()
    }

    /// Analog-path full dot product with a per-cycle fresh-varied ADC —
    /// the Monte-Carlo inference path (σ in volts on ADC references).
    pub fn dot_analog_mc(&self, inputs: &[Trit], sigma_v: f64, rng: &mut Rng) -> Vec<i32> {
        assert_eq!(inputs.len(), self.storage.n_rows());
        let mut out = vec![0i32; self.storage.n_cols()];
        for cycle in 0..self.storage.n_rows() / GROUP_ROWS {
            let base = cycle * GROUP_ROWS;
            let adc = VoltageAdc::with_variation(&self.bitline, sigma_v, rng);
            let part = self.mac_cycle_analog(base, &inputs[base..base + GROUP_ROWS], Some(&adc));
            for (o, p) in out.iter_mut().zip(part) {
                *o += p;
            }
        }
        out
    }
}

impl CimArray for SiTeCim1Array {
    fn design(&self) -> Design {
        Design::Cim1
    }

    fn storage(&self) -> &TernaryStorage {
        &self.storage
    }

    fn storage_mut(&mut self) -> &mut TernaryStorage {
        &mut self.storage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::mac::{dot_ref, Flavor};
    use crate::util::rng::Rng;

    fn loaded_array(seed: u64, sparsity: f64) -> (SiTeCim1Array, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let mut a = SiTeCim1Array::with_dims(Tech::Sram8T, 64, 32);
        a.write_matrix(&rng.ternary_vec(64 * 32, sparsity));
        let inputs = rng.ternary_vec(64, sparsity);
        (a, inputs)
    }

    #[test]
    fn read_row_returns_weights() {
        let mut rng = Rng::new(3);
        let mut a = SiTeCim1Array::with_dims(Tech::Femfet3T, 32, 16);
        let w = rng.ternary_vec(32 * 16, 0.3);
        a.write_matrix(&w);
        for r in 0..32 {
            assert_eq!(a.read_row(r), w[r * 16..(r + 1) * 16]);
        }
    }

    #[test]
    fn dot_matches_reference_semantics() {
        let (a, inputs) = loaded_array(21, 0.4);
        assert_eq!(a.dot(&inputs), dot_ref(a.storage(), &inputs, Flavor::Cim1));
    }

    #[test]
    fn analog_ideal_equals_digital() {
        // With ideal ADC references the analog path must reproduce the
        // digital saturating semantics bit-for-bit.
        let (a, inputs) = loaded_array(22, 0.5);
        for cycle in 0..4 {
            let base = cycle * 16;
            let dig = a.mac_cycle(cycle, &inputs[base..base + 16]);
            let ana = a.mac_cycle_analog(base, &inputs[base..base + 16], None);
            assert_eq!(dig, ana, "cycle {cycle}");
        }
    }

    #[test]
    fn mc_with_zero_sigma_is_exactly_ideal() {
        let (a, inputs) = loaded_array(23, 0.4);
        let mut rng = Rng::new(1);
        assert_eq!(a.dot_analog_mc(&inputs, 0.0, &mut rng), a.dot(&inputs));
    }

    #[test]
    fn mc_with_realistic_sigma_rarely_deviates() {
        let (a, inputs) = loaded_array(24, 0.5);
        let mut rng = Rng::new(2);
        let ideal = a.dot(&inputs);
        let mut deviations = 0usize;
        for _ in 0..20 {
            let mc = a.dot_analog_mc(&inputs, 0.008, &mut rng);
            deviations += mc.iter().zip(&ideal).filter(|(m, i)| m != i).count();
        }
        // 8 mV σ against ≥40 mV margins: deviations should be rare (<2%).
        assert!(deviations < 20 * 32 / 50, "deviations = {deviations}");
    }

    #[test]
    fn zero_inputs_zero_output() {
        let (a, _) = loaded_array(25, 0.2);
        let out = a.dot(&vec![0i8; 64]);
        assert!(out.iter().all(|&o| o == 0));
    }
}
