//! `sitecim` CLI — leader entrypoint for the SiTe CiM reproduction.
//! See `sitecim help` (or cli::USAGE) for subcommands.

fn main() {
    let args = sitecim::util::cli::Args::from_env();
    match sitecim::cli::run(args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
