//! Circuit layer: bit-line electrical models, sensing circuitry (voltage
//! and current mode, comparator + analog subtractor), the 3-bit flash ADC
//! with the extra output-8 sense amplifier, and the sense-margin analysis
//! engines behind Fig 4(c) and Fig 7(c).

pub mod adc;
pub mod bitline;
pub mod sense_margin;
pub mod sensing;

pub use adc::{CurrentAdc, VoltageAdc, ADC_MAX};
pub use bitline::VoltageBitline;
pub use sense_margin::{current_mode_margins, voltage_mode_margins, MarginPoint};
