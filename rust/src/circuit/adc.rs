//! 3-bit flash ADC model (plus the extra sense amplifier for output 8).
//!
//! The paper digitizes each RBL with a 3-bit flash ADC whose references
//! sit at the midpoints of the (non-linear) RBL voltage levels, and adds
//! one extra SA so the value 8 is also detectable; outputs 9..16 saturate
//! to 8 (§III.2, §IV.3). The same quantizer is reused in current mode for
//! SiTe CiM II (references in units of ΔI instead of volts).
//!
//! Monte-Carlo variation: each comparator's reference can be offset by a
//! Gaussian (σ_ref) to model V_TH variation in the sensing stack — this
//! drives the error-probability analysis (repro ERR).

use super::bitline::VoltageBitline;
use crate::device::PeriphParams;
use crate::util::rng::Rng;

/// Saturating code range of the 3-bit converter + extra SA.
pub const ADC_MAX: u32 = 8;

/// Voltage-mode flash ADC bound to a calibrated bit-line model.
#[derive(Clone, Debug)]
pub struct VoltageAdc {
    /// References between codes n-1 and n, for n = 1..=8 (descending V).
    refs: Vec<f64>,
}

impl VoltageAdc {
    /// Build from the bit-line model with ideal midpoint references.
    pub fn ideal(bl: &VoltageBitline) -> VoltageAdc {
        VoltageAdc { refs: (1..=ADC_MAX as usize).map(|n| bl.reference(n)).collect() }
    }

    /// Build with Gaussian reference offsets (σ volts) — one MC sample.
    pub fn with_variation(bl: &VoltageBitline, sigma: f64, rng: &mut Rng) -> VoltageAdc {
        VoltageAdc {
            refs: (1..=ADC_MAX as usize)
                .map(|n| bl.reference(n) + rng.normal_ms(0.0, sigma))
                .collect(),
        }
    }

    /// Quantize an RBL voltage to a code 0..=8 (thermometer search: the
    /// number of references the voltage has fallen below).
    pub fn quantize(&self, v_rbl: f64) -> u32 {
        let mut code = 0u32;
        for &r in &self.refs {
            if v_rbl < r {
                code += 1;
            }
        }
        code
    }
}

/// Current-mode quantizer for SiTe CiM II: input is |I_RBL1 − I_RBL2| in
/// units of (I_LRS − I_HRS); references at half-integers.
#[derive(Clone, Debug)]
pub struct CurrentAdc {
    refs: Vec<f64>,
}

impl CurrentAdc {
    pub fn ideal() -> CurrentAdc {
        CurrentAdc { refs: (1..=ADC_MAX as usize).map(|n| n as f64 - 0.5).collect() }
    }

    pub fn with_variation(sigma_units: f64, rng: &mut Rng) -> CurrentAdc {
        CurrentAdc {
            refs: (1..=ADC_MAX as usize)
                .map(|n| n as f64 - 0.5 + rng.normal_ms(0.0, sigma_units))
                .collect(),
        }
    }

    /// Quantize a normalized magnitude to a code 0..=8.
    pub fn quantize(&self, mag_units: f64) -> u32 {
        let mut code = 0u32;
        for &r in &self.refs {
            if mag_units > r {
                code += 1;
            }
        }
        code
    }
}

/// ADC cost accessors (shared 45 nm periphery).
pub fn adc_energy(p: &PeriphParams) -> f64 {
    p.e_adc + p.e_sa_extra
}
pub fn adc_time(p: &PeriphParams) -> f64 {
    p.t_adc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ideal_voltage_adc_recovers_count() {
        let bl = VoltageBitline::new(1.0);
        let adc = VoltageAdc::ideal(&bl);
        for n in 0..=8usize {
            assert_eq!(adc.quantize(bl.v_after(n)), n as u32, "n={n}");
        }
    }

    #[test]
    fn voltage_adc_saturates_at_8() {
        let bl = VoltageBitline::new(1.0);
        let adc = VoltageAdc::ideal(&bl);
        for n in 9..=16usize {
            assert_eq!(adc.quantize(bl.v_after(n)), 8, "n={n}");
        }
    }

    #[test]
    fn ideal_current_adc_recovers_count() {
        let adc = CurrentAdc::ideal();
        for n in 0..=8u32 {
            assert_eq!(adc.quantize(n as f64), n);
        }
        assert_eq!(adc.quantize(12.0), 8);
    }

    #[test]
    fn small_variation_rarely_flips() {
        let bl = VoltageBitline::new(1.0);
        let mut rng = Rng::new(1);
        let mut errors = 0;
        let trials = 2000;
        for _ in 0..trials {
            let adc = VoltageAdc::with_variation(&bl, 0.005, &mut rng);
            for n in 0..=8usize {
                if adc.quantize(bl.v_after(n)) != n as u32 {
                    errors += 1;
                }
            }
        }
        // σ = 5 mV against ≥40 mV margins: ~8σ, errors essentially zero.
        assert!(errors < trials / 100, "errors={errors}");
    }

    #[test]
    fn large_variation_does_flip() {
        let bl = VoltageBitline::new(1.0);
        let mut rng = Rng::new(2);
        let mut errors = 0;
        for _ in 0..500 {
            let adc = VoltageAdc::with_variation(&bl, 0.04, &mut rng);
            for n in 0..=8usize {
                if adc.quantize(bl.v_after(n)) != n as u32 {
                    errors += 1;
                }
            }
        }
        assert!(errors > 0);
    }
}
