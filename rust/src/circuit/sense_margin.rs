//! Sense-margin analysis engines for both SiTe CiM flavors.
//!
//! - Voltage mode (SiTe CiM I, Fig 4(c)): margins fall straight out of the
//!   calibrated `VoltageBitline` discharge model.
//! - Current mode (SiTe CiM II, Fig 7): the paper's best-case/worst-case
//!   loading construction. For an expected output O = n (one polarity):
//!   BC: n rows at (I,W)=(1,1), the rest at (0,0) → minimum RBL current;
//!   WC: n rows at (1,1), the rest at (1,0) → every idle row still parks
//!   I_HRS-effective (LRBL charging) on both RBLs → maximum loading.
//!   SM(n−1↔n) = (O_BC(n) − O_WC(n−1)) / 2 in unit-current terms.

use super::bitline::VoltageBitline;
use super::sensing::{i_hrs_effective, CurrentSense};
use crate::device::TechParams;

/// One row of a sense-margin table.
#[derive(Clone, Copy, Debug)]
pub struct MarginPoint {
    /// Expected output value n (number of unit discharges / unit currents).
    pub n: usize,
    /// The physical level for output n (V for voltage mode; normalized
    /// units for current mode, best-case).
    pub level: f64,
    /// Sense margin between n−1 and n (same unit as `level`).
    pub margin: f64,
}

/// Fig 4(c): RBL voltage and sense margin vs number of discharges, 0..=max.
pub fn voltage_mode_margins(vdd: f64, max_n: usize) -> Vec<MarginPoint> {
    let bl = VoltageBitline::new(vdd);
    (0..=max_n)
        .map(|n| MarginPoint {
            n,
            level: bl.v_after(n),
            margin: if n == 0 { f64::NAN } else { bl.sense_margin(n) },
        })
        .collect()
}

/// Current-mode analysis inputs.
#[derive(Clone, Debug)]
pub struct CurrentModeSetup {
    pub n_rows_block_total: usize, // rows asserted per MAC cycle (16)
    pub c_lrbl: f64,               // local RBL capacitance (F)
    pub t_sense: f64,              // sense window (s)
}

/// Normalized output for a given (n_lrs on RBL, idle rows contributing
/// I_HRS on both RBLs) configuration.
fn output_units(
    p: &TechParams,
    cs: &CurrentSense,
    n: usize,
    idle_rows: usize,
    i_hrs_eff: f64,
) -> f64 {
    // RBL carrying the signal: n LRS paths + idle_rows HRS-effective.
    let i_sig = cs.loaded_current(p, n, idle_rows, i_hrs_eff);
    // The opposite RBL: idle rows park HRS-effective current there too,
    // plus the n active rows' complementary cells (M2 = 0 → HRS).
    let i_ref = cs.loaded_current(p, 0, idle_rows + n, i_hrs_eff);
    let unit = p.i_lrs - i_hrs_eff;
    (i_sig - i_ref) / unit
}

/// Fig 7(c): sense margin for expected outputs 0..=16 under BC/WC loading.
pub fn current_mode_margins(p: &TechParams, setup: &CurrentModeSetup) -> Vec<MarginPoint> {
    let cs = CurrentSense::default_for(p);
    let i_hrs_eff = i_hrs_effective(p, setup.c_lrbl, setup.t_sense);
    let total = setup.n_rows_block_total;
    let bc = |n: usize| output_units(p, &cs, n, 0, i_hrs_eff);
    let wc = |n: usize| output_units(p, &cs, n, total - n, i_hrs_eff);
    (0..=total)
        .map(|n| {
            let margin = if n == 0 {
                f64::NAN
            } else {
                (bc(n) - wc(n - 1)) / 2.0
            };
            MarginPoint { n, level: bc(n), margin }
        })
        .collect()
}

/// The paper's robustness target: SM > 40 mV (voltage) / the equivalent
/// 0.40-unit margin (current mode, half the ideal 0.5-unit spacing × the
/// same 0.8 derating the voltage design tolerates at n = 8).
pub const SM_TARGET_V: f64 = 0.040;
pub const SM_TARGET_UNITS: f64 = 0.40;

/// Largest n whose margin still meets the target (the "how many rows can
/// we assert" design decision; both designs land on 8 → 3-bit ADC).
pub fn max_robust_output_v(points: &[MarginPoint]) -> usize {
    points
        .iter()
        .filter(|p| p.n > 0 && p.margin >= SM_TARGET_V - 1e-7)
        .map(|p| p.n)
        .max()
        .unwrap_or(0)
}

pub fn max_robust_output_units(points: &[MarginPoint]) -> usize {
    points
        .iter()
        .filter(|p| p.n > 0 && p.margin >= SM_TARGET_UNITS - 1e-7)
        .map(|p| p.n)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Tech, TechParams};

    fn setup() -> CurrentModeSetup {
        CurrentModeSetup { n_rows_block_total: 16, c_lrbl: 1.0e-15, t_sense: 0.45e-9 }
    }

    #[test]
    fn voltage_mode_8_rows_robust() {
        let pts = voltage_mode_margins(1.0, 16);
        assert_eq!(max_robust_output_v(&pts), 8);
    }

    #[test]
    fn current_mode_margin_shrinks_with_output() {
        let p = TechParams::new(Tech::Femfet3T);
        let pts = current_mode_margins(&p, &setup());
        assert_eq!(pts.len(), 17);
        let m1 = pts[1].margin;
        let m16 = pts[16].margin;
        assert!(m1 > m16, "SM(1)={m1} SM(16)={m16}");
    }

    #[test]
    fn current_mode_diminishes_beyond_8() {
        // Paper §IV.4: "SM begins to diminish for O > 8" — the margin at
        // 16 must be clearly below the margin at small outputs.
        let p = TechParams::new(Tech::Sram8T);
        let pts = current_mode_margins(&p, &setup());
        let robust = max_robust_output_units(&pts);
        assert!((7..=9).contains(&robust), "robust output bound = {robust}");
    }

    #[test]
    fn current_mode_bc_levels_track_n_with_loading_droop() {
        // The best-case level for output n is n minus the (growing)
        // loading droop — within ~15% of ideal through the robust range.
        let p = TechParams::new(Tech::Sram8T);
        let pts = current_mode_margins(&p, &setup());
        for pt in pts.iter().take(9).skip(1) {
            assert!(pt.level <= pt.n as f64 + 1e-9, "n={} level={}", pt.n, pt.level);
            assert!(pt.level > 0.84 * pt.n as f64, "n={} level={}", pt.n, pt.level);
        }
    }

    #[test]
    fn works_for_all_techs() {
        for t in Tech::ALL {
            let p = TechParams::new(t);
            let pts = current_mode_margins(&p, &setup());
            assert!(pts[1].margin > 0.3, "{:?}: SM(1)={}", t, pts[1].margin);
        }
    }
}
