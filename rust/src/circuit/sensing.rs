//! Sensing circuitry: voltage sense amps, the current-mode sense path of
//! SiTe CiM II (comparator + analog current subtractor, Fig 6), and the
//! loaded current-summation model used for its sense-margin analysis.
//!
//! Current-mode loading model (§IV.4): the sensing network presents an
//! effective resistance R_sense on each RBL; the total RBL current causes
//! a source-side droop V_drop = I_total·R_sense which reduces every
//! LRS path's drive, I_eff = I_lrs·(1 − α·I_total·R_sense/VDD). This is
//! why the worst-case (max-loading) and best-case (min-loading) examples
//! of Fig 7(a,b) diverge, shrinking the margin at high outputs.

use crate::device::TechParams;

/// Effective capacitance charging current seen as "HRS current" in CiM II
/// (§IV.1: "a small current that flows from RBL to charge the LRBL cap").
/// Average over the sense window.
pub fn i_hrs_effective(p: &TechParams, c_lrbl: f64, t_sense: f64) -> f64 {
    // Q = C·VDD delivered over the sense window, plus the true off current.
    c_lrbl * p.vdd / t_sense.max(1e-12) + p.i_hrs
}

/// Current-sensing load model.
#[derive(Clone, Debug)]
pub struct CurrentSense {
    /// Effective sensing resistance per RBL (Ω).
    pub r_sense: f64,
    /// Drive-reduction coefficient (dimensionless, ≈1).
    pub alpha: f64,
    pub vdd: f64,
}

impl CurrentSense {
    /// Calibrated default: α·I_LRS·R_sense/VDD ≈ 1.6% per active row, which
    /// lands SM ≈ 0.5 units at O=1, ≈ 0.4 at O=8 and clearly below beyond
    /// (mirroring Fig 7(c): "SM begins to diminish for O > 8").
    pub fn default_for(p: &TechParams) -> CurrentSense {
        let beta = 0.016; // per-row drive loss at I_LRS
        CurrentSense { r_sense: beta * p.vdd / p.i_lrs, alpha: 1.0, vdd: p.vdd }
    }

    /// Solve the loaded RBL current for a column where `n_lrs` LRS paths
    /// and `n_hrs` HRS paths conduct (fixed-point, 2 iterations suffice
    /// because the droop is small).
    pub fn loaded_current(&self, p: &TechParams, n_lrs: usize, n_hrs_eff: usize, i_hrs_eff: f64) -> f64 {
        let ideal = n_lrs as f64 * p.i_lrs + n_hrs_eff as f64 * i_hrs_eff;
        let mut total = ideal;
        for _ in 0..3 {
            let droop = (self.alpha * total * self.r_sense / self.vdd).min(0.9);
            total = n_lrs as f64 * p.i_lrs * (1.0 - droop) + n_hrs_eff as f64 * i_hrs_eff;
        }
        total
    }
}

/// The comparator of Fig 6(a): which RBL carries more current → sign.
pub fn comparator_sign(i_rbl1: f64, i_rbl2: f64) -> i32 {
    if i_rbl1 >= i_rbl2 {
        1
    } else {
        -1
    }
}

/// The analog current subtractor of Fig 6(b): |I1 − I2| normalized to the
/// unit current (I_LRS − I_HRS); the ADC digitizes this magnitude.
pub fn subtractor_magnitude_units(i_rbl1: f64, i_rbl2: f64, unit: f64) -> f64 {
    (i_rbl1 - i_rbl2).abs() / unit.max(1e-18)
}

/// Voltage sense amplifier: resolves once the develop margin exceeds its
/// offset; models as fixed resolve time + energy from `TechParams`.
#[derive(Clone, Copy, Debug)]
pub struct VoltageSenseAmp {
    pub t_resolve: f64,
    pub energy: f64,
}

impl VoltageSenseAmp {
    pub fn from_tech(p: &TechParams) -> VoltageSenseAmp {
        VoltageSenseAmp { t_resolve: p.t_sa_v, energy: p.e_sa_v }
    }

    /// Binary decision: discharged (stored '1') vs held (stored '0').
    pub fn sense(&self, v_rbl: f64, vdd: f64, threshold_frac: f64) -> bool {
        v_rbl < vdd * threshold_frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Tech, TechParams};

    fn p() -> TechParams {
        TechParams::new(Tech::Sram8T)
    }

    #[test]
    fn hrs_effective_dominated_by_lrbl_charging() {
        let p = p();
        let i = i_hrs_effective(&p, 1e-15, 0.45e-9);
        assert!(i > p.i_hrs * 10.0, "i_hrs_eff = {i}");
        assert!(i < p.i_lrs / 5.0, "should stay well below LRS: {i}");
    }

    #[test]
    fn loading_reduces_current_sublinearly() {
        let p = p();
        let cs = CurrentSense::default_for(&p);
        let one = cs.loaded_current(&p, 1, 0, 0.0);
        let sixteen = cs.loaded_current(&p, 16, 0, 0.0);
        assert!(one <= p.i_lrs * 1.0 + 1e-12);
        assert!(sixteen < 16.0 * one, "no loading effect visible");
        assert!(sixteen > 12.0 * one, "loading too strong: {sixteen} vs {one}");
    }

    #[test]
    fn comparator_and_subtractor() {
        assert_eq!(comparator_sign(2e-6, 1e-6), 1);
        assert_eq!(comparator_sign(1e-6, 2e-6), -1);
        let m = subtractor_magnitude_units(5e-6, 2e-6, 1e-6);
        assert!((m - 3.0).abs() < 1e-9);
    }

    #[test]
    fn voltage_sa_thresholds() {
        let p = p();
        let sa = VoltageSenseAmp::from_tech(&p);
        assert!(sa.sense(0.85, 1.0, 0.95));
        assert!(!sa.sense(0.99, 1.0, 0.95));
    }
}
