//! Read-bit-line (RBL) electrical models.
//!
//! Two views of the same wire:
//! 1. `discharge_time` / `precharge_energy`: RC arithmetic used by the
//!    timing/energy models.
//! 2. `VoltageBitline`: the calibrated multi-row discharge model behind
//!    Fig 4(c) — the per-discharge increment δ_n shrinks with n because
//!    the drive current of each pull-down path drops as the RBL falls
//!    ("exponential behavior of bit-line capacitance discharging", §III.2).
//!
//! Calibration (DESIGN.md §5): δ_n = δ₀·exp(−(n−1)/τ_d) with δ₀ = 100 mV
//! and τ_d = 31.39 chosen so SM(1) = δ₁/2 = 50 mV and SM(8) = δ₈/2 =
//! 40 mV — the two anchor points the paper states.

use crate::device::TechParams;

/// Per-discharge increment anchor: δ₀ = 100 mV.
pub const DELTA0_V: f64 = 0.100;
/// Decay constant τ_d for the sensed range (n ≤ 8): solves
/// δ₀·exp(−7/τ_d) = 80 mV (SM(8) = 40 mV).
pub fn tau_d() -> f64 {
    7.0 / (DELTA0_V / 0.080).ln()
}
/// Deep-discharge compression constant for n > 8: once the RBL has fallen
/// ~0.7 V the read stacks leave saturation and the increments collapse —
/// this keeps the 16-level ladder inside the 1 V swing and produces the
/// paper's "SM becomes even lower for higher values" regime.
pub const TAU_DEEP: f64 = 2.5;

/// Time for a single on-cell to discharge `delta_v` from an RBL of
/// capacitance `c` at drive `i_on` (s).
pub fn discharge_time(c: f64, delta_v: f64, i_on: f64) -> f64 {
    c * delta_v / i_on.max(1e-15)
}

/// Energy the precharge circuit spends restoring the RBL from
/// `v_now` to `vdd` (J): Q·V_supply = C·(vdd − v_now)·vdd.
pub fn precharge_energy(c: f64, vdd: f64, v_now: f64) -> f64 {
    c * (vdd - v_now).max(0.0) * vdd
}

/// Energy to drive a line from 0 to `vdd` (full-swing), used by
/// current-sensing bit-lines that start each CiM II cycle at 0 (§V.2b).
pub fn full_swing_energy(c: f64, vdd: f64) -> f64 {
    c * vdd * vdd
}

/// The calibrated voltage-mode multi-discharge model.
#[derive(Clone, Debug)]
pub struct VoltageBitline {
    pub vdd: f64,
    pub delta0: f64,
    pub tau_d: f64,
}

impl VoltageBitline {
    pub fn new(vdd: f64) -> VoltageBitline {
        VoltageBitline { vdd, delta0: DELTA0_V, tau_d: tau_d() }
    }

    /// The n-th discharge increment δ_n (1-based), volts. Piecewise:
    /// slow roll-off through the robust range (n ≤ 8), fast compression
    /// beyond it (see `TAU_DEEP`).
    pub fn delta(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        if n <= 8 {
            self.delta0 * (-((n - 1) as f64) / self.tau_d).exp()
        } else {
            let d8 = self.delta0 * (-7.0 / self.tau_d).exp();
            d8 * (-((n - 8) as f64) / TAU_DEEP).exp()
        }
    }

    /// RBL voltage after `n` simultaneous unit discharges.
    pub fn v_after(&self, n: usize) -> f64 {
        let mut v = self.vdd;
        for i in 1..=n {
            v -= self.delta(i);
        }
        v.max(0.0)
    }

    /// Sense margin between outputs n−1 and n: half the voltage gap.
    pub fn sense_margin(&self, n: usize) -> f64 {
        if n == 0 {
            return self.vdd; // "0 vs anything" is trivially robust
        }
        (self.v_after(n - 1) - self.v_after(n)) / 2.0
    }

    /// Ideal ADC reference level between codes n−1 and n (midpoint).
    pub fn reference(&self, n: usize) -> f64 {
        (self.v_after(n - 1) + self.v_after(n)) / 2.0
    }
}

/// RBL capacitance for a SiTe CiM I column: every ternary cell hangs TWO
/// read-port junctions on each RBL (AX1 + AX4 on RBL1; AX2 + AX3 on RBL2),
/// versus one in the NM baseline — the root of the read overheads (§V.1c).
pub fn c_rbl_cim1(p: &TechParams, n_rows: usize, cell_h_f: f64) -> f64 {
    p.c_rbl(n_rows, 2.0, cell_h_f)
}

/// NM baseline column: one junction per cell per RBL.
pub fn c_rbl_nm(p: &TechParams, n_rows: usize) -> f64 {
    p.c_rbl(n_rows, 1.0, p.cell_h_f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Tech, TechParams};

    #[test]
    fn calibration_anchors() {
        let bl = VoltageBitline::new(1.0);
        assert!((bl.sense_margin(1) - 0.050).abs() < 1e-6, "SM(1)={}", bl.sense_margin(1));
        assert!((bl.sense_margin(8) - 0.040).abs() < 1e-4, "SM(8)={}", bl.sense_margin(8));
    }

    #[test]
    fn sense_margin_monotone_decreasing() {
        let bl = VoltageBitline::new(1.0);
        for n in 2..=16 {
            assert!(bl.sense_margin(n) < bl.sense_margin(n - 1));
        }
    }

    #[test]
    fn sm_below_target_beyond_8() {
        let bl = VoltageBitline::new(1.0);
        // The paper's robustness constraint: SM > 40 mV holds to n = 8,
        // is violated beyond (§III.2).
        assert!(bl.sense_margin(8) >= 0.0399);
        assert!(bl.sense_margin(9) < 0.040);
        assert!(bl.sense_margin(16) < 0.040);
    }

    #[test]
    fn v_after_monotone_and_bounded() {
        let bl = VoltageBitline::new(1.0);
        let mut last = 1.0 + 1e-12;
        for n in 0..=20 {
            let v = bl.v_after(n);
            assert!(v < last, "not strictly decreasing at n={n}");
            assert!(v > 0.0, "ladder fell out of the 1 V swing at n={n}");
            last = v;
        }
    }

    #[test]
    fn sixteen_levels_fit_in_swing() {
        // The paper asserts 16 rows with outputs 9..16 approximated to 8;
        // the physical levels must still be distinct and non-negative.
        let bl = VoltageBitline::new(1.0);
        assert!(bl.v_after(16) > 0.05, "v(16) = {}", bl.v_after(16));
    }

    #[test]
    fn references_sit_between_levels() {
        let bl = VoltageBitline::new(1.0);
        for n in 1..=8 {
            let r = bl.reference(n);
            assert!(r < bl.v_after(n - 1) && r > bl.v_after(n));
        }
    }

    #[test]
    fn rc_helpers() {
        let t = discharge_time(35e-15, 0.1, 50e-6);
        assert!(t > 10e-12 && t < 1e-9, "t={t}");
        let e = precharge_energy(35e-15, 1.0, 0.9);
        assert!((e - 3.5e-15).abs() < 1e-18);
        assert!(full_swing_energy(35e-15, 1.0) > e);
    }

    #[test]
    fn cim1_column_cap_larger_than_nm() {
        let p = TechParams::new(Tech::Sram8T);
        assert!(c_rbl_cim1(&p, 256, p.cell_h_f) > c_rbl_nm(&p, 256));
    }
}
