"""Build-time training of the ternary MLP (straight-through estimator)
on a synthetic 8x8 digit corpus.

The corpus: ten fixed prototype glyphs (deterministic from the seed),
each sample = prototype + Gaussian pixel noise, ternarized to {-1,0,+1}.
This stands in for the paper's (proprietary-pipeline) benchmark training
runs — see DESIGN.md §1. Training is full-precision weights with TWN
ternarization applied through an STE, and STE-ternarized activations, so
the network the accelerator executes is exactly what was trained.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .model import ACT_THRESHOLDS, DIMS

TWN_FACTOR = 0.7


# ----------------------------- dataset -----------------------------------
def make_dataset(n_train=4096, n_test=1024, seed=7, noise=1.05):
    """Synthetic ternary digit corpus: ((x_train, y_train), (x_test, y_test))."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, size=(10, 64)).astype(np.float32)

    def sample(n):
        y = rng.integers(0, 10, size=n)
        x = protos[y] + rng.normal(0.0, noise, size=(n, 64)).astype(np.float32)
        # Ternarize pixels around +-0.5.
        xt = np.where(x > 0.5, 1, np.where(x < -0.5, -1, 0)).astype(np.int8)
        return xt, y.astype(np.int32)

    return sample(n_train), sample(n_test)


# ----------------------------- STE ops ------------------------------------
@jax.custom_vjp
def ste_ternarize_w(w):
    """TWN weight ternarization with straight-through gradient."""
    delta = TWN_FACTOR * jnp.mean(jnp.abs(w))
    return jnp.where(w > delta, 1.0, jnp.where(w < -delta, -1.0, 0.0))


def _stw_fwd(w):
    return ste_ternarize_w(w), None


def _stw_bwd(_, g):
    return (g,)


ste_ternarize_w.defvjp(_stw_fwd, _stw_bwd)


@jax.custom_vjp
def ste_ternarize_a(z, theta):
    return jnp.where(z > theta, 1.0, jnp.where(z < -theta, -1.0, 0.0))


def _sta_fwd(z, theta):
    return ste_ternarize_a(z, theta), (z, theta)


def _sta_bwd(res, g):
    z, theta = res
    # Pass gradient inside a window around the thresholds (hard-tanh STE).
    mask = (jnp.abs(z) < 2.0 * theta).astype(g.dtype)
    return (g * mask, None)


ste_ternarize_a.defvjp(_sta_fwd, _sta_bwd)


# ----------------------------- training -----------------------------------
def init_params(seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(DIMS) - 1)
    return [
        jax.random.normal(k, (DIMS[i], DIMS[i + 1])) * (1.5 / np.sqrt(DIMS[i]))
        for i, k in enumerate(ks)
    ]


def forward_train(params, x):
    h = x.astype(jnp.float32)
    for li, w in enumerate(params[:-1]):
        z = h @ ste_ternarize_w(w)
        h = ste_ternarize_a(z, ACT_THRESHOLDS[li])
    return h @ ste_ternarize_w(params[-1])


def loss_fn(params, x, y):
    logits = forward_train(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@functools.partial(jax.jit, static_argnames=())
def adam_step(params, m, v, t, x, y, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss


def export_ternary(params):
    """Float params -> int8 ternary weights + per-layer TWN scales."""
    weights, scales = [], []
    for w in params:
        wn = np.asarray(w)
        delta = TWN_FACTOR * np.mean(np.abs(wn))
        t = np.where(wn > delta, 1, np.where(wn < -delta, -1, 0)).astype(np.int8)
        surv = np.abs(wn)[np.abs(wn) > delta]
        scales.append(float(surv.mean()) if surv.size else 1.0)
        weights.append(t)
    return weights, scales


def train(steps=400, batch=128, seed=7, log_every=50, verbose=False):
    """Train and return (ternary_weights, scales, log dict)."""
    (xtr, ytr), (xte, yte) = make_dataset(seed=seed)
    params = init_params(seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(seed)
    losses = []
    for t in range(1, steps + 1):
        idx = rng.integers(0, len(xtr), size=batch)
        params, m, v, loss = adam_step(
            params, m, v, t, jnp.array(xtr[idx], jnp.float32), jnp.array(ytr[idx])
        )
        if t % log_every == 0 or t == 1:
            losses.append((t, float(loss)))
            if verbose:
                print(f"step {t:4d} loss {float(loss):.4f}")
    weights, scales = export_ternary(params)
    log = {
        "steps": steps,
        "batch": batch,
        "seed": seed,
        "loss_curve": losses,
        "final_loss": losses[-1][1],
    }
    return weights, scales, (xte, yte), log
