"""Generate the committed example artifact under
``rust/tests/data/example_artifact``.

A tiny, fully deterministic version-2 artifact — seeded ternary weights,
per-file SHA-256 checksums and a placement plan from the stdlib
placement mirror — committed to the repo so CI can exercise the
artifact contract end to end without jax/numpy:

- ``sitecim artifact verify rust/tests/data/example_artifact`` checks
  the schema version, re-hashes every file and replays the plan against
  the Rust packing rules;
- the ``multi_tenant`` test battery loads it, asserts the Python plan
  equals ``plan_layout``'s Rust recomputation shard for shard, and
  strict-replays it through ``TernaryGemmEngine::program_from_plan``.

The pool geometry is deliberately small (64x32 arrays, 6 slots) so the
Rust tests can instantiate a matching engine cheaply; the weights span
multiple k- and n-shards so the plan is not trivial. Standard library
only; regenerate with ``python3 -m compile.make_example_artifact`` from
``python/`` (the output is byte-stable, so a regeneration diff means
the placement rules changed).
"""

from __future__ import annotations

import hashlib
import json
import os

from .placement import placement_manifest_entry

ARRAY_ROWS = 64
ARRAY_COLS = 32
SLOTS = 6
DIMS = [150, 60, 10]
TEST_N = 4
OUT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "data", "example_artifact"
)


def ternary_stream(seed: int):
    """Deterministic trits via SHA-256 in counter mode (no RNG module
    dependency, stable across Python versions)."""
    counter = 0
    while True:
        block = hashlib.sha256(seed.to_bytes(8, "little") + counter.to_bytes(8, "little"))
        for byte in block.digest():
            # 0..255 -> {-1, 0, +1} with a mild bias toward zero.
            yield (byte % 3) - 1 if byte % 2 == 0 else 0
        counter += 1


def take_bytes(stream, count: int) -> bytes:
    """``count`` trits as the two's-complement bytes the runtime reads."""
    return bytes((next(stream)) & 0xFF for _ in range(count))


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    files = {}
    weights = []
    for i in range(len(DIMS) - 1):
        k, n = DIMS[i], DIMS[i + 1]
        files[f"w{i}.bin"] = take_bytes(ternary_stream(100 + i), k * n)
        weights.append({"file": f"w{i}.bin", "shape": [k, n]})
    files["test_x.bin"] = take_bytes(ternary_stream(200), TEST_N * DIMS[0])
    files["test_y.bin"] = bytes(i % DIMS[-1] for i in range(TEST_N))
    for name, data in files.items():
        with open(os.path.join(OUT_DIR, name), "wb") as f:
            f.write(data)

    layers = [(DIMS[i], DIMS[i + 1]) for i in range(len(DIMS) - 1)]
    placement = placement_manifest_entry(layers, ARRAY_ROWS, ARRAY_COLS, SLOTS)
    assert placement is not None, "example model must fit its plan pool"
    manifest = {
        "version": 2,
        "batch": 4,
        "dims": DIMS,
        "act_thresholds": [0.5] * (len(DIMS) - 2),
        "kernel_shape": [8, 16, 16],
        "files": {},
        "weights": weights,
        "scales": [1.0],
        "sha256": {name: hashlib.sha256(data).hexdigest() for name, data in files.items()},
        "placement": placement,
        "test_set": {
            "x": "test_x.bin",
            "y": "test_y.bin",
            "n": TEST_N,
            "in_dim": DIMS[0],
        },
        "accuracy": {},
    }
    path = os.path.join(OUT_DIR, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}: dims {DIMS}, {len(placement['shards'])} planned shards")


if __name__ == "__main__":
    main()
