"""AOT compile path: train the ternary MLP, lower the inference graphs to
HLO *text* and write all runtime artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to --out (default ../artifacts):
  mlp_cim1.hlo.txt     batch-32 CiM-I MLP forward (Pallas kernel inlined)
  mlp_cim2.hlo.txt     same, CiM-II saturation semantics
  mlp_exact.hlo.txt    unsaturated (NM-reference) forward
  kernel_MxKxN.hlo.txt standalone CiM matmul (for the rust equivalence test)
  w0.bin w1.bin w2.bin ternary weights, row-major int8
  test_x.bin test_y.bin  held-out synthetic-digit test set (int8 / uint8)
  manifest.json        shapes, files, scales, training log, accuracies

Since PR 7 the manifest is *versioned* (schema version 2): it carries a
``sha256`` map over every referenced data file (the runtime verifies
them eagerly at load) and a ``placement`` plan — the shelf-packed
resident layout computed analytically by ``placement.plan_layout``, the
stdlib mirror of the engine's ``TileCache`` — so a serving cold start
programs arrays straight from the artifact instead of discovering
placement on first traffic. ``sitecim artifact verify <dir>`` checks all
of it offline.

Python runs ONCE (make artifacts); the rust binary is self-contained
afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.sitecim_mac import cim_matmul
from .model import accuracy, mlp_infer, mlp_infer_exact
from .placement import placement_manifest_entry
from .train import train

BATCH = 32
KERNEL_SHAPE = (16, 64, 32)  # (M, K, N) for the standalone kernel artifact
MANIFEST_VERSION = 2  # keep in sync with rust/src/runtime/artifact.rs

# Placement plans target the paper's default engine geometry: 256×256
# arrays, 2 Mword pool = 32 arrays (EngineConfig defaults on the rust
# side). A plan is advisory — engines at other geometries just fall back
# to discovery-on-first-traffic.
PLAN_ARRAY_ROWS = 256
PLAN_ARRAY_COLS = 256
PLAN_SLOTS = 32


def sha256_file(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_mlp(weights, flavor):
    # Weights cross the AOT boundary as f32 *parameters*, not baked int8
    # constants: xla_extension 0.5.1's HLO-text parser mishandles large
    # s8 dense constants (observed as garbled logits), while the f32
    # parameter path is the well-trodden one.
    xspec = jax.ShapeDtypeStruct((BATCH, 64), jnp.float32)
    wspecs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in weights]

    if flavor == "exact":
        def fn(x, *wf):
            return (mlp_infer_exact(x, [w.astype(jnp.int8) for w in wf]),)
    else:
        def fn(x, *wf):
            return (mlp_infer(x, [w.astype(jnp.int8) for w in wf], flavor),)

    return to_hlo_text(jax.jit(fn).lower(xspec, *wspecs))


def lower_kernel(flavor="cim1"):
    m, k, n = KERNEL_SHAPE
    xs = jax.ShapeDtypeStruct((m, k), jnp.float32)
    ws = jax.ShapeDtypeStruct((k, n), jnp.float32)

    def fn(x, w):
        out = cim_matmul(x.astype(jnp.int8), w.astype(jnp.int8), flavor)
        return (out.astype(jnp.float32),)

    return to_hlo_text(jax.jit(fn).lower(xs, ws))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("SITECIM_TRAIN_STEPS", 400)))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print(f"[aot] training ternary MLP ({args.steps} steps)...")
    weights, scales, (xte, yte), log = train(steps=args.steps, verbose=True, log_every=100)

    # Accuracy report (full test set, reference semantics).
    wj = [jnp.array(w) for w in weights]
    xf = jnp.array(xte, jnp.float32)
    yj = jnp.array(yte)
    accs = {
        "exact": float(accuracy(mlp_infer_exact(xf, wj), yj)),
        "cim1": float(accuracy(mlp_infer(xf, wj, "cim1", use_kernel=False), yj)),
        "cim2": float(accuracy(mlp_infer(xf, wj, "cim2", use_kernel=False), yj)),
    }
    print(f"[aot] test accuracy: {accs}")

    files = {}
    for flavor in ("cim1", "cim2", "exact"):
        name = f"mlp_{flavor}.hlo.txt"
        text = lower_mlp(weights, flavor)
        open(os.path.join(args.out, name), "w").write(text)
        files[f"mlp_{flavor}"] = name
        print(f"[aot] wrote {name} ({len(text)} chars)")

    m, k, n = KERNEL_SHAPE
    kname = f"kernel_{m}x{k}x{n}.hlo.txt"
    open(os.path.join(args.out, kname), "w").write(lower_kernel("cim1"))
    files["kernel"] = kname
    print(f"[aot] wrote {kname}")

    wfiles = []
    for i, w in enumerate(weights):
        fname = f"w{i}.bin"
        w.astype(np.int8).tofile(os.path.join(args.out, fname))
        wfiles.append({"file": fname, "shape": list(w.shape)})
    xte.astype(np.int8).tofile(os.path.join(args.out, "test_x.bin"))
    yte.astype(np.uint8).tofile(os.path.join(args.out, "test_y.bin"))

    dims = [64, 256, 128, 10]
    layers = [(dims[i], dims[i + 1]) for i in range(len(dims) - 1)]
    placement = placement_manifest_entry(layers, PLAN_ARRAY_ROWS, PLAN_ARRAY_COLS, PLAN_SLOTS)
    data_files = [wf["file"] for wf in wfiles] + ["test_x.bin", "test_y.bin"]
    manifest = {
        "version": MANIFEST_VERSION,
        "batch": BATCH,
        "dims": dims,
        "act_thresholds": [6.0, 5.0],
        "kernel_shape": list(KERNEL_SHAPE),
        "files": files,
        "weights": wfiles,
        "scales": scales,
        "test_set": {"x": "test_x.bin", "y": "test_y.bin", "n": int(len(yte)), "in_dim": 64},
        "sha256": {f: sha256_file(os.path.join(args.out, f)) for f in data_files},
        "accuracy": accs,
        "training": log,
    }
    if placement is not None:
        manifest["placement"] = placement
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest.json; done.")


if __name__ == "__main__":
    main()
