"""Analytic mirror of the engine's resident-weight placement rules.

The AOT compiler emits, alongside the weights, a *placement plan*: the
shelf/shard assignments the engine's ``TileCache`` would compute on an
empty partition, so cold-start can program arrays straight from the
artifact instead of discovering placement on first traffic. This module
mirrors, line for line, the Rust side it must agree with:

- shard decomposition and flat order: ``engine/tiling.rs``
  (``TileGrid::tiles`` iterates n-tiles outer / k-tiles inner;
  ``TileGrid::shards`` splits each tile with the n-offset outer and the
  k-offset inner);
- region allocation: ``engine/resident.rs`` (``SlotSpace::alloc``
  first-fit shelf packing — reuse a free span of a tall-enough shelf,
  else open a new shelf at the high-water mark — with all row counts
  padded to whole 16-row MAC groups) over slots in ascending index
  order, exactly what ``TileCache::place`` does when nothing is resident
  and nothing needs evicting.

``rust/src/engine/resident.rs::plan_layout`` is the same computation in
Rust; the committed example artifact (generated here, strict-verified by
``sitecim artifact verify`` and replayed by ``program_from_plan`` in the
Rust tests) pins the two mirrors against each other in CI.

Standard library only — importable without jax/numpy (unlike ``aot``).
"""

from __future__ import annotations

GROUP_ROWS = 16


def pad_rows(rows: int) -> int:
    """Round ``rows`` up to whole 16-row MAC groups (``div_ceil * 16``)."""
    return -(-rows // GROUP_ROWS) * GROUP_ROWS


def grid_shards(k, n, tile_rows, tile_cols, array_rows, array_cols):
    """Shards of a ``k x n`` weight in the engine's flat order.

    Mirrors ``TileGrid::new(k, n, tile_rows, tile_cols)
    .shards(array_rows, array_cols)``: tiles iterate n-outer/k-inner,
    and each tile splits into array-fitting shards n-offset-outer /
    k-offset-inner. Returns dicts with ``k0/k_len/n0/n_len``.
    """
    assert k > 0 and n > 0, "weights have positive dimensions"
    assert tile_rows % GROUP_ROWS == 0, "tile rows keep whole MAC groups"
    shards = []
    n_tiles = -(-n // tile_cols)
    k_tiles = -(-k // tile_rows)
    for nt in range(n_tiles):
        n0 = nt * tile_cols
        n_len = min(tile_cols, n - n0)
        for kt in range(k_tiles):
            k0 = kt * tile_rows
            k_len = min(tile_rows, k - k0)
            for n_off in range(0, n_len, array_cols):
                for k_off in range(0, k_len, array_rows):
                    shards.append(
                        {
                            "k0": k0 + k_off,
                            "k_len": min(array_rows, k_len - k_off),
                            "n0": n0 + n_off,
                            "n_len": min(array_cols, n_len - n_off),
                        }
                    )
    return shards


class SlotSpace:
    """One pool array's free space: first-fit shelf packing.

    Mirrors ``SlotSpace::alloc`` in ``engine/resident.rs``: reuse the
    first free span of the first tall-enough shelf (``shelf.rows >=
    rows``, splitting the span and keeping the leftover free), else open
    a new shelf at the high-water mark. Rects carry the *requested*
    padded row count even on a taller reused shelf.
    """

    def __init__(self):
        # Shelves are dicts {row0, rows, segs}; segs are dicts
        # {col0, cols, used} partitioning [0, slot_cols).
        self.shelves = []
        self.used_rows = 0

    def alloc(self, slot_rows, slot_cols, rows, cols):
        """Place a padded ``rows x cols`` region; None when it won't fit."""
        for shelf in self.shelves:
            if shelf["rows"] < rows:
                continue
            for i, seg in enumerate(shelf["segs"]):
                if not seg["used"] and seg["cols"] >= cols:
                    col0 = seg["col0"]
                    extra = seg["cols"] - cols
                    seg["cols"] = cols
                    seg["used"] = True
                    if extra > 0:
                        shelf["segs"].insert(
                            i + 1, {"col0": col0 + cols, "cols": extra, "used": False}
                        )
                    return {"row0": shelf["row0"], "rows": rows, "col0": col0, "cols": cols}
        if self.used_rows + rows <= slot_rows and cols <= slot_cols:
            row0 = self.used_rows
            self.used_rows += rows
            segs = [{"col0": 0, "cols": cols, "used": True}]
            if cols < slot_cols:
                segs.append({"col0": cols, "cols": slot_cols - cols, "used": False})
            self.shelves.append({"row0": row0, "rows": rows, "segs": segs})
            return {"row0": row0, "rows": rows, "col0": 0, "cols": cols}
        return None


def plan_layout(layers, array_rows, array_cols, n_slots):
    """Placement plan for ``layers`` ([(k, n), ...]) on an empty
    ``n_slots``-array partition, or None when the working set does not
    fit without eviction (a plan is only meaningful if cold-start can
    program it wholesale). Slots are scanned in ascending index order
    per shard, exactly like ``TileCache::place`` on an empty cache; the
    recorded ``slot`` is the partition-relative rank.
    """
    slots = [SlotSpace() for _ in range(n_slots)]
    plan = []
    for li, (k, n) in enumerate(layers):
        shards = grid_shards(k, n, array_rows, array_cols, array_rows, array_cols)
        for si, sh in enumerate(shards):
            rows = pad_rows(sh["k_len"])
            assert rows <= array_rows and sh["n_len"] <= array_cols
            placed = None
            for s, space in enumerate(slots):
                rect = space.alloc(array_rows, array_cols, rows, sh["n_len"])
                if rect is not None:
                    placed = (s, rect)
                    break
            if placed is None:
                return None
            slot, rect = placed
            plan.append(
                {
                    "layer": li,
                    "shard": si,
                    "k0": sh["k0"],
                    "k_len": sh["k_len"],
                    "n0": sh["n0"],
                    "n_len": sh["n_len"],
                    "slot": slot,
                    "row0": rect["row0"],
                    "col0": rect["col0"],
                }
            )
    return plan


def placement_manifest_entry(layers, array_rows, array_cols, n_slots):
    """The manifest ``placement`` object for ``layers``, or None when no
    eviction-free plan exists at this pool size."""
    plan = plan_layout(layers, array_rows, array_cols, n_slots)
    if plan is None:
        return None
    return {
        "array_rows": array_rows,
        "array_cols": array_cols,
        "slots": n_slots,
        "shards": plan,
    }
