"""Layer-2 JAX model: a ternary MLP whose matmuls run through the SiTe
CiM Pallas kernel (Layer 1).

Architecture (synthetic 8x8 digit corpus): 64 -> 256 -> 128 -> 10.
All reduction dims are multiples of 16 (the array's MAC-cycle group).

Two inference graphs are exported:
- `mlp_infer(..., flavor)`: every matmul uses the saturating CiM kernel —
  this is what the accelerator computes;
- `mlp_infer_exact`: unsaturated ternary matmuls — the NM-baseline
  reference used to quantify the accuracy cost of the 3-bit ADC clamp.

Interface convention for the AOT boundary: activations cross as f32
tensors holding ternary values (the PJRT literal path for f32 is the
best-trodden one); weights are baked into the graph as int8 constants.
"""

import jax.numpy as jnp

from .kernels.ref import cim_matmul_ref, exact_matmul_ref
from .kernels.sitecim_mac import cim_matmul

# Layer sizes.
DIMS = (64, 256, 128, 10)
# Fixed activation-ternarization thresholds (calibrated during training:
# pre-activation std ~ sqrt(fan_in * density); threshold ~0.7 x mean abs).
ACT_THRESHOLDS = (6.0, 5.0)


def ternarize_acts(z, theta):
    """Signed ternary activation: sign(z) * 1[|z| > theta]."""
    return jnp.where(z > theta, 1, jnp.where(z < -theta, -1, 0)).astype(jnp.int8)


def mlp_infer(x_f32, weights, flavor="cim1", use_kernel=True):
    """Ternary MLP forward with CiM (saturating) matmuls.

    x_f32: (B, 64) f32 holding trits; weights: list of int8 (K, N).
    Returns (B, 10) f32 logits.
    """
    matmul = cim_matmul if use_kernel else cim_matmul_ref
    h = x_f32.astype(jnp.int8)
    for li, w in enumerate(weights[:-1]):
        z = matmul(h, w, flavor)
        h = ternarize_acts(z, ACT_THRESHOLDS[li])
    logits = matmul(h, weights[-1], flavor)
    return logits.astype(jnp.float32)


def mlp_infer_exact(x_f32, weights):
    """Same network with exact (NM baseline) ternary matmuls."""
    h = x_f32.astype(jnp.int8)
    for li, w in enumerate(weights[:-1]):
        z = exact_matmul_ref(h, w)
        h = ternarize_acts(z, ACT_THRESHOLDS[li])
    logits = exact_matmul_ref(h, weights[-1])
    return logits.astype(jnp.float32)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
