"""Layer-1 Pallas kernel: the SiTe CiM saturating ternary matmul.

Hardware adaptation (DESIGN.md §6): the analog array's parallelism
(16 wordlines x 256 columns per cycle) becomes a blocked MXU-style
formulation. The grid tiles (M, N); each program instance holds an
(block_m, K) activation tile and a (K, block_n) weight tile in VMEM and
walks K in 16-row groups — exactly the array's MAC-cycle granularity —
applying the 3-bit-ADC saturation per group before accumulating into the
output tile. On a real TPU the int8 products feed the MXU and the clamp
is a cheap VPU op; on this image the kernel runs with interpret=True
(Mosaic lowering is TPU-only) so structure, not wallclock, is what the
kernel optimizes.

VMEM footprint per program instance (int8/int32):
    x tile: block_m*K, w tile: K*block_n, out: block_m*block_n*4
e.g. block_m=64, block_n=128, K=1024 -> 64 KiB + 128 KiB + 32 KiB,
comfortably inside a TPU core's ~16 MiB VMEM with double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 16
SAT = 8


def _mac_kernel(x_ref, w_ref, o_ref, *, flavor: str):
    """One (block_m, block_n) output tile; K walked in 16-row groups."""
    x = x_ref[...].astype(jnp.int32)  # (bm, K)
    w = w_ref[...].astype(jnp.int32)  # (K, bn)
    bm, k = x.shape
    bn = w.shape[1]
    groups = k // GROUP

    def body(g, acc):
        xg = jax.lax.dynamic_slice(x, (0, g * GROUP), (bm, GROUP))
        wg = jax.lax.dynamic_slice(w, (g * GROUP, 0), (GROUP, bn))
        prod = xg[:, :, None] * wg[None, :, :]  # (bm, GROUP, bn)
        a = jnp.sum(prod == 1, axis=1, dtype=jnp.int32)
        b = jnp.sum(prod == -1, axis=1, dtype=jnp.int32)
        if flavor == "cim1":
            part = jnp.minimum(a, SAT) - jnp.minimum(b, SAT)
        else:  # cim2
            d = a - b
            part = jnp.sign(d) * jnp.minimum(jnp.abs(d), SAT)
        return acc + part

    o_ref[...] = jax.lax.fori_loop(0, groups, body, jnp.zeros((bm, bn), jnp.int32))


@functools.partial(jax.jit, static_argnames=("flavor", "block_m", "block_n"))
def cim_matmul(x, w, flavor="cim1", block_m=None, block_n=None):
    """Saturating ternary matmul via the Pallas kernel.

    x: (M, K) int8 trits, w: (K, N) int8 trits -> (M, N) int32.
    M and N must be divisible by the chosen block sizes; K by 16.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert k % GROUP == 0, f"K={k} must be a multiple of {GROUP}"
    bm = block_m or _pick_block(m, 64)
    bn = block_n or _pick_block(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    kern = functools.partial(_mac_kernel, flavor=flavor)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)


def _pick_block(dim, preferred):
    """Largest divisor of `dim` not exceeding `preferred`."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def vmem_footprint_bytes(block_m, block_n, k):
    """Estimated VMEM bytes per program instance (for DESIGN.md §Perf)."""
    return block_m * k + k * block_n + 4 * block_m * block_n
