"""Pure-jnp oracle for the SiTe CiM saturating ternary matmul.

This is the numerical *specification* of what the arrays compute
(mirrors rust `array::mac::Flavor`):

- inputs x (M, K) and weights w (K, N) are signed ternary (int8 in
  {-1, 0, +1});
- the K dimension is processed in groups of 16 rows (one MAC cycle);
- per group and output column, a = #(+1 products), b = #(-1 products);
- SiTe CiM I digitizes a and b separately with 3-bit ADCs (+ extra SA):
  partial = min(a, 8) - min(b, 8);
- SiTe CiM II subtracts first, then digitizes the magnitude:
  partial = sign(a-b) * min(|a-b|, 8);
- partials accumulate exactly in the digital periphery (PCUs).
"""

import jax.numpy as jnp

GROUP = 16
SAT = 8


def _group_counts(x, w):
    """Per-group (+1, -1) product counts.

    x: (M, K) int8, w: (K, N) int8 -> a, b: (M, K//GROUP, N) int32.
    """
    m, k = x.shape
    assert k % GROUP == 0, f"K={k} must be a multiple of {GROUP}"
    n = w.shape[1]
    xg = x.reshape(m, k // GROUP, GROUP).astype(jnp.int32)
    wg = w.reshape(k // GROUP, GROUP, n).astype(jnp.int32)
    # products: (M, K//GROUP, GROUP, N)
    prod = xg[:, :, :, None] * wg[None, :, :, :]
    a = jnp.sum(prod == 1, axis=2, dtype=jnp.int32)
    b = jnp.sum(prod == -1, axis=2, dtype=jnp.int32)
    return a, b


def cim_matmul_ref(x, w, flavor="cim1"):
    """Saturating ternary matmul, (M, K) x (K, N) -> (M, N) int32."""
    a, b = _group_counts(x, w)
    if flavor == "cim1":
        part = jnp.minimum(a, SAT) - jnp.minimum(b, SAT)
    elif flavor == "cim2":
        d = a - b
        part = jnp.sign(d) * jnp.minimum(jnp.abs(d), SAT)
    else:
        raise ValueError(f"unknown flavor {flavor!r}")
    return jnp.sum(part, axis=1, dtype=jnp.int32)


def exact_matmul_ref(x, w):
    """Unsaturated ternary matmul (the NM baseline / accuracy reference)."""
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
