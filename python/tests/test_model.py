"""L2 correctness: model shapes, kernel-vs-ref equivalence inside the
full forward pass, and exact-vs-CiM accuracy behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ACT_THRESHOLDS,
    DIMS,
    accuracy,
    mlp_infer,
    mlp_infer_exact,
    ternarize_acts,
)


@pytest.fixture(scope="module")
def weights():
    rng = np.random.default_rng(3)
    ws = []
    for i in range(len(DIMS) - 1):
        ws.append(rng.integers(-1, 2, size=(DIMS[i], DIMS[i + 1])).astype(np.int8))
    return [jnp.array(w) for w in ws]


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(4)
    return jnp.array(rng.integers(-1, 2, size=(32, DIMS[0])), jnp.float32)


def test_logit_shapes(weights, batch):
    for fl in ("cim1", "cim2"):
        out = mlp_infer(batch, weights, fl, use_kernel=False)
        assert out.shape == (32, DIMS[-1])
        assert out.dtype == jnp.float32


def test_kernel_and_ref_paths_agree(weights, batch):
    for fl in ("cim1", "cim2"):
        via_kernel = mlp_infer(batch, weights, fl, use_kernel=True)
        via_ref = mlp_infer(batch, weights, fl, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(via_kernel), np.asarray(via_ref))


def test_ternarize_acts_range(weights, batch):
    t = ternarize_acts(jnp.array([[10.0, -10.0, 0.1, -0.1]]), 5.0)
    np.testing.assert_array_equal(np.asarray(t), [[1, -1, 0, 0]])


def test_thresholds_cover_hidden_layers():
    assert len(ACT_THRESHOLDS) == len(DIMS) - 2


def test_cim_close_to_exact_on_random_net(weights, batch):
    exact = np.argmax(np.asarray(mlp_infer_exact(batch, weights)), axis=1)
    cim = np.argmax(np.asarray(mlp_infer(batch, weights, "cim1", use_kernel=False)), axis=1)
    # Random nets saturate more than trained ones; still mostly agree.
    assert np.mean(exact == cim) > 0.5


def test_accuracy_helper():
    logits = jnp.array([[0.0, 1.0], [1.0, 0.0]])
    labels = jnp.array([1, 0])
    assert float(accuracy(logits, labels)) == 1.0
