"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (multiples of the 16-row group), sparsity and
flavor; plus directed edge cases for the ADC saturation semantics.
This is the CORE correctness signal for the compute hot-spot.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import cim_matmul_ref, exact_matmul_ref
from compile.kernels.sitecim_mac import cim_matmul, vmem_footprint_bytes


def random_trits(rng, shape, p_zero):
    u = rng.random(shape)
    return np.where(u < p_zero, 0, np.where(u < p_zero + (1 - p_zero) / 2, 1, -1)).astype(
        np.int8
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8).map(lambda v: v * 4),
    kg=st.integers(1, 8),
    n=st.integers(1, 6).map(lambda v: v * 8),
    p_zero=st.floats(0.0, 0.9),
    flavor=st.sampled_from(["cim1", "cim2"]),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref(m, kg, n, p_zero, flavor, seed):
    rng = np.random.default_rng(seed)
    k = kg * 16
    x = random_trits(rng, (m, k), p_zero)
    w = random_trits(rng, (k, n), p_zero)
    got = np.asarray(cim_matmul(jnp.array(x), jnp.array(w), flavor))
    want = np.asarray(cim_matmul_ref(jnp.array(x), jnp.array(w), flavor))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    kg=st.integers(1, 6),
    seed=st.integers(0, 2**31),
)
def test_sparse_saturating_close_to_exact(kg, seed):
    # At realistic sparsity the clamp rarely binds: results differ little
    # from the exact ternary matmul.
    rng = np.random.default_rng(seed)
    k = kg * 16
    x = random_trits(rng, (8, k), 0.6)
    w = random_trits(rng, (k, 16), 0.6)
    sat = np.asarray(cim_matmul_ref(jnp.array(x), jnp.array(w), "cim1"))
    exact = np.asarray(exact_matmul_ref(jnp.array(x), jnp.array(w)))
    assert np.mean(sat != exact) < 0.12


class TestSaturationSemantics:
    def _one_group(self, xrow, wcol, flavor):
        x = jnp.array(np.array(xrow, np.int8).reshape(1, 16))
        w = jnp.array(np.array(wcol, np.int8).reshape(16, 1))
        return int(np.asarray(cim_matmul_ref(x, w, flavor))[0, 0])

    def test_all_agree_saturates_to_8(self):
        assert self._one_group([1] * 16, [1] * 16, "cim1") == 8
        assert self._one_group([1] * 16, [1] * 16, "cim2") == 8
        assert self._one_group([-1] * 16, [1] * 16, "cim1") == -8

    def test_flavor_divergence_on_double_saturation(self):
        # a = 10, b = 6: CiM I -> 8-6 = 2; CiM II -> min(4,8) = 4.
        x = [1] * 16
        w = [1] * 10 + [-1] * 6
        assert self._one_group(x, w, "cim1") == 2
        assert self._one_group(x, w, "cim2") == 4

    def test_zero_inputs_give_zero(self):
        assert self._one_group([0] * 16, [1] * 16, "cim1") == 0

    def test_i_times_w_signs(self):
        # I = -1 row flips the stored weight (the cross-coupling case).
        x = [-1] + [0] * 15
        w = [1] + [0] * 15
        assert self._one_group(x, w, "cim1") == -1
        assert self._one_group(x, w, "cim2") == -1


def test_rejects_non_group_multiple_k():
    x = jnp.zeros((4, 20), jnp.int8)
    w = jnp.zeros((20, 8), jnp.int8)
    with pytest.raises(AssertionError):
        cim_matmul(x, w)


def test_vmem_footprint_within_tpu_budget():
    # DESIGN.md §Perf: chosen blocks must fit VMEM with double buffering.
    assert vmem_footprint_bytes(64, 128, 1024) < 4 * 1024 * 1024
