"""AOT path: HLO text lowering is well-formed and the artifacts
directory (if built) is internally consistent."""

import json
import os

import pytest

from compile.aot import KERNEL_SHAPE, lower_kernel

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_kernel_produces_hlo_text():
    text = lower_kernel("cim1")
    assert "HloModule" in text
    assert "ENTRY" in text
    # Interpret-mode pallas must lower to plain HLO — no Mosaic
    # custom-calls the CPU PJRT client can't run.
    assert "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestArtifacts:
    @pytest.fixture(autouse=True)
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.m = json.load(f)

    def test_manifest_files_exist(self):
        for f in self.m["files"].values():
            assert os.path.exists(os.path.join(ART, f)), f
        for w in self.m["weights"]:
            assert os.path.exists(os.path.join(ART, w["file"]))

    def test_weight_sizes_match_shapes(self):
        for w in self.m["weights"]:
            size = os.path.getsize(os.path.join(ART, w["file"]))
            assert size == w["shape"][0] * w["shape"][1]

    def test_testset_sizes(self):
        ts = self.m["test_set"]
        n, d = ts["n"], ts["in_dim"]
        assert os.path.getsize(os.path.join(ART, ts["x"])) == n * d
        assert os.path.getsize(os.path.join(ART, ts["y"])) == n

    def test_recorded_accuracy_is_high_and_cim_close(self):
        acc = self.m["accuracy"]
        assert acc["exact"] > 0.9
        assert acc["exact"] - acc["cim1"] < 0.02
        assert acc["exact"] - acc["cim2"] < 0.02

    def test_kernel_shape_recorded(self):
        assert tuple(self.m["kernel_shape"]) == KERNEL_SHAPE
