"""Training pipeline: loss decreases, exports are well-formed, and the
trained ternary network loses almost nothing to the CiM saturation —
the paper's 'mild accuracy degradation' claim on our substitute corpus."""

import jax.numpy as jnp
import numpy as np

from compile.model import accuracy, mlp_infer, mlp_infer_exact
from compile.train import export_ternary, init_params, make_dataset, train


def test_dataset_is_ternary_and_balanced():
    (xtr, ytr), (xte, yte) = make_dataset(n_train=512, n_test=256, seed=1)
    assert xtr.dtype == np.int8
    assert set(np.unique(xtr)).issubset({-1, 0, 1})
    assert xtr.shape == (512, 64)
    counts = np.bincount(yte, minlength=10)
    assert counts.min() > 5  # all classes present


def test_dataset_deterministic():
    a = make_dataset(n_train=64, n_test=32, seed=9)[0][0]
    b = make_dataset(n_train=64, n_test=32, seed=9)[0][0]
    np.testing.assert_array_equal(a, b)


def test_loss_decreases_in_smoke_train():
    _, _, _, log = train(steps=60, batch=64, log_every=59)
    first = log["loss_curve"][0][1]
    last = log["loss_curve"][-1][1]
    assert last < first * 0.5, f"loss {first} -> {last}"


def test_export_ternary_wellformed():
    params = init_params(2)
    weights, scales = export_ternary(params)
    for w, p in zip(weights, params):
        assert w.dtype == np.int8
        assert w.shape == p.shape
        assert set(np.unique(w)).issubset({-1, 0, 1})
        # TWN: a meaningful fraction of zeros.
        z = np.mean(w == 0)
        assert 0.2 < z < 0.7
    assert all(s > 0 for s in scales)


def test_trained_net_cim_accuracy_close_to_exact():
    weights, _, (xte, yte), _ = train(steps=250, batch=128)
    wj = [jnp.array(w) for w in weights]
    xf = jnp.array(xte, jnp.float32)
    yj = jnp.array(yte)
    a_exact = float(accuracy(mlp_infer_exact(xf, wj), yj))
    a_cim1 = float(accuracy(mlp_infer(xf, wj, "cim1", use_kernel=False), yj))
    a_cim2 = float(accuracy(mlp_infer(xf, wj, "cim2", use_kernel=False), yj))
    assert a_exact > 0.9
    # Paper: negligible accuracy impact from CiM saturation.
    assert a_exact - a_cim1 < 0.02, (a_exact, a_cim1)
    assert a_exact - a_cim2 < 0.02, (a_exact, a_cim2)
